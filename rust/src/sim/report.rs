//! Simulation results: the numbers behind Figs. 4–5 and the headline.

use crate::util::stats::percentile;

/// Per-query outcome.
#[derive(Clone, Copy, Debug)]
pub struct QueryOutcome {
    pub query_id: u64,
    pub system: usize,
    pub arrival_s: f64,
    pub start_s: f64,
    pub finish_s: f64,
    pub service_s: f64,
    pub energy_j: f64,
}

impl QueryOutcome {
    pub fn latency_s(&self) -> f64 {
        self.finish_s - self.arrival_s
    }

    pub fn queue_wait_s(&self) -> f64 {
        self.start_s - self.arrival_s
    }
}

/// Per-system totals.
#[derive(Clone, Debug, Default)]
pub struct SystemTotals {
    pub name: String,
    pub queries: u64,
    pub busy_s: f64,
    pub energy_j: f64,
}

/// Per-system batch-dispatch statistics. Serial simulation is reported
/// as one dispatch per query (every batch has size 1), so serial and
/// batched reports are directly comparable.
#[derive(Clone, Debug, Default)]
pub struct BatchStats {
    /// batches dispatched to this system
    pub dispatches: u64,
    /// `size_hist[k]` = batches of size `k + 1`
    pub size_hist: Vec<u64>,
    /// energy burned in dispatch-overhead phases (J) — the component
    /// batching amortizes
    pub dispatch_energy_j: f64,
    /// straggler drag: Σ over batches of Σ members `max(n) − n_member` —
    /// decode steps short members idled inside batches while the longest
    /// member finished. 0 in serial mode (every batch is a singleton);
    /// the number shape-aware formation exists to shrink.
    pub straggler_decode_steps: u64,
}

impl BatchStats {
    pub fn record(&mut self, size: usize, dispatch_energy_j: f64, straggler_steps: u64) {
        self.dispatches += 1;
        if self.size_hist.len() < size {
            self.size_hist.resize(size, 0);
        }
        self.size_hist[size - 1] += 1;
        self.dispatch_energy_j += dispatch_energy_j;
        self.straggler_decode_steps += straggler_steps;
    }

    /// queries served through this system's dispatches
    pub fn queries(&self) -> u64 {
        self.size_hist.iter().enumerate().map(|(i, &c)| (i as u64 + 1) * c).sum()
    }

    pub fn mean_size(&self) -> f64 {
        if self.dispatches == 0 {
            return 0.0;
        }
        self.queries() as f64 / self.dispatches as f64
    }
}

/// Full simulation report.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub policy: String,
    pub outcomes: Vec<QueryOutcome>,
    pub systems: Vec<SystemTotals>,
    pub makespan_s: f64,
    /// Σ per-query service time — the paper's "runtime" axis in
    /// Figs. 4(b)/5(b) (serial compute time, queueing excluded)
    pub total_service_s: f64,
    pub total_energy_j: f64,
    /// idle-floor energy burned by all nodes over the makespan when the
    /// experiment includes always-on attribution
    pub idle_energy_j: f64,
    /// queries the engine re-routed to the cheapest feasible system
    /// because the policy picked an infeasible one (always 0 in strict
    /// mode, which panics instead)
    pub rerouted: u64,
    /// per-system dispatch/batch-size statistics, in system order
    pub batches: Vec<BatchStats>,
    /// what the realized routing would have cost executed one query per
    /// dispatch (Σ per-query `E` over the same assignment, idle
    /// excluded). Equals `total_energy_j − idle_energy_j` in serial
    /// mode; the gap to it is the energy batching saved.
    pub serial_energy_j: f64,
}

impl SimReport {
    pub fn mean_latency_s(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().map(|o| o.latency_s()).sum::<f64>() / self.outcomes.len() as f64
    }

    pub fn p99_latency_s(&self) -> f64 {
        let v: Vec<f64> = self.outcomes.iter().map(|o| o.latency_s()).collect();
        if v.is_empty() {
            0.0
        } else {
            percentile(&v, 99.0)
        }
    }

    pub fn energy_per_query(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.total_energy_j / self.outcomes.len() as f64
    }

    /// conservation check: Σ query energy == Σ system energy
    pub fn energy_conserved(&self) -> bool {
        let by_query: f64 = self.outcomes.iter().map(|o| o.energy_j).sum();
        let by_system: f64 = self.systems.iter().map(|s| s.energy_j).sum();
        (by_query - by_system).abs() <= 1e-6 * by_system.max(1.0)
    }

    /// queries routed to each system, in system order
    pub fn routing_counts(&self) -> Vec<u64> {
        self.systems.iter().map(|s| s.queries).collect()
    }

    /// total dispatch-overhead energy across systems (J)
    pub fn dispatch_energy_j(&self) -> f64 {
        self.batches.iter().map(|b| b.dispatch_energy_j).sum()
    }

    /// total batches dispatched across systems
    pub fn total_dispatches(&self) -> u64 {
        self.batches.iter().map(|b| b.dispatches).sum()
    }

    /// total straggler decode steps across systems (0 in serial mode)
    pub fn total_straggler_steps(&self) -> u64 {
        self.batches.iter().map(|b| b.straggler_decode_steps).sum()
    }

    /// mean batch size across all dispatches (1.0 in serial mode)
    pub fn mean_batch_size(&self) -> f64 {
        let d = self.total_dispatches();
        if d == 0 {
            return 0.0;
        }
        self.batches.iter().map(BatchStats::queries).sum::<u64>() as f64 / d as f64
    }

    /// energy saved by batching vs running the same assignment one query
    /// per dispatch (J, positive = batching saved energy; 0 in serial
    /// mode by construction)
    pub fn batching_energy_delta_j(&self) -> f64 {
        self.serial_energy_j - (self.total_energy_j - self.idle_energy_j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_math() {
        let o = QueryOutcome {
            query_id: 0,
            system: 0,
            arrival_s: 1.0,
            start_s: 3.0,
            finish_s: 7.0,
            service_s: 4.0,
            energy_j: 10.0,
        };
        assert_eq!(o.latency_s(), 6.0);
        assert_eq!(o.queue_wait_s(), 2.0);
    }

    #[test]
    fn conservation_detects_mismatch() {
        let mut r = SimReport {
            policy: "t".into(),
            outcomes: vec![QueryOutcome {
                query_id: 0,
                system: 0,
                arrival_s: 0.0,
                start_s: 0.0,
                finish_s: 1.0,
                service_s: 1.0,
                energy_j: 5.0,
            }],
            systems: vec![SystemTotals { name: "x".into(), queries: 1, busy_s: 1.0, energy_j: 5.0 }],
            makespan_s: 1.0,
            total_service_s: 1.0,
            total_energy_j: 5.0,
            idle_energy_j: 0.0,
            rerouted: 0,
            batches: vec![BatchStats::default()],
            serial_energy_j: 5.0,
        };
        assert!(r.energy_conserved());
        r.systems[0].energy_j = 6.0;
        assert!(!r.energy_conserved());
    }

    #[test]
    fn batch_stats_histogram_and_means() {
        let mut b = BatchStats::default();
        b.record(1, 2.0, 0);
        b.record(4, 2.0, 7);
        b.record(4, 2.0, 5);
        assert_eq!(b.dispatches, 3);
        assert_eq!(b.size_hist, vec![1, 0, 0, 2]);
        assert_eq!(b.queries(), 9);
        assert!((b.mean_size() - 3.0).abs() < 1e-12);
        assert!((b.dispatch_energy_j - 6.0).abs() < 1e-12);
        assert_eq!(b.straggler_decode_steps, 12);
    }
}
