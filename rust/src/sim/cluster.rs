//! Cluster state for simulation and live routing: per-system FIFO queues
//! over `count` identical nodes.

use crate::hw::catalog::SystemId;
use crate::hw::spec::SystemSpec;

/// Dynamic state of one system class (possibly multiple nodes).
#[derive(Clone, Debug)]
pub struct NodeState {
    pub spec: SystemSpec,
    /// next instant each node becomes free (s)
    pub node_free_at: Vec<f64>,
    /// queued + in-flight estimated service seconds (for JSQ / views)
    pub queue_depth_s: f64,
    pub queue_len: usize,
    /// totals
    pub busy_s: f64,
    pub energy_j: f64,
    pub queries: u64,
}

impl NodeState {
    pub fn new(spec: SystemSpec) -> Self {
        let nodes = spec.count.max(1);
        Self {
            spec,
            node_free_at: vec![0.0; nodes],
            queue_depth_s: 0.0,
            queue_len: 0,
            busy_s: 0.0,
            energy_j: 0.0,
            queries: 0,
        }
    }

    /// Earliest node availability.
    pub fn earliest_free(&self) -> f64 {
        self.node_free_at.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Schedule a service of `dur` starting no earlier than `t`; returns
    /// (start, finish).
    pub fn schedule(&mut self, t: f64, dur: f64) -> (f64, f64) {
        let (idx, &free_at) = self
            .node_free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .expect("system has nodes");
        let start = t.max(free_at);
        let finish = start + dur;
        self.node_free_at[idx] = finish;
        self.busy_s += dur;
        self.queries += 1;
        (start, finish)
    }
}

/// The cluster: all system states, indexable by `SystemId`.
#[derive(Clone, Debug)]
pub struct ClusterState {
    pub nodes: Vec<NodeState>,
}

impl ClusterState {
    pub fn new(systems: &[SystemSpec]) -> Self {
        Self { nodes: systems.iter().cloned().map(NodeState::new).collect() }
    }

    pub fn get(&self, id: SystemId) -> &NodeState {
        &self.nodes[id.0]
    }

    pub fn get_mut(&mut self, id: SystemId) -> &mut NodeState {
        &mut self.nodes[id.0]
    }

    pub fn queue_depths(&self) -> Vec<f64> {
        self.nodes.iter().map(|n| n.queue_depth_s).collect()
    }

    pub fn queue_lens(&self) -> Vec<usize> {
        self.nodes.iter().map(|n| n.queue_len).collect()
    }

    /// Makespan: when the last node finishes.
    pub fn makespan(&self) -> f64 {
        self.nodes
            .iter()
            .flat_map(|n| n.node_free_at.iter().copied())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;

    #[test]
    fn schedule_serializes_on_one_node() {
        let mut specs = system_catalog();
        specs[0].count = 1;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        let (s1, f1) = n.schedule(0.0, 2.0);
        let (s2, f2) = n.schedule(0.0, 3.0);
        assert_eq!((s1, f1), (0.0, 2.0));
        assert_eq!((s2, f2), (2.0, 5.0)); // waits for node
        assert_eq!(n.busy_s, 5.0);
        assert_eq!(n.queries, 2);
    }

    #[test]
    fn multiple_nodes_run_parallel() {
        let mut specs = system_catalog();
        specs[0].count = 2;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        let (_, f1) = n.schedule(0.0, 2.0);
        let (s2, f2) = n.schedule(0.0, 2.0);
        assert_eq!(f1, 2.0);
        assert_eq!(s2, 0.0); // second node picks it up immediately
        assert_eq!(f2, 2.0);
    }

    #[test]
    fn makespan_is_max_over_nodes() {
        let specs = system_catalog();
        let mut cs = ClusterState::new(&specs);
        cs.get_mut(SystemId(0)).schedule(0.0, 5.0);
        cs.get_mut(SystemId(1)).schedule(0.0, 9.0);
        assert_eq!(cs.makespan(), 9.0);
    }
}
