//! Cluster state for simulation and live routing: per-system FIFO queues
//! over `count` identical nodes.
//!
//! Queue state is **derived, never cached**: `queue_len` counts the
//! in-flight assignments whose finish instant lies beyond the observed
//! time (a min-heap pruned by [`NodeState::advance_to`]), and
//! `queue_depth_at` integrates outstanding seconds from `node_free_at`.
//! The seed code cached both on the node and only ever incremented them,
//! so online policies routed on cumulative arrival counts — the
//! regression tests in `sim::engine` pin the fixed behavior.

use crate::hw::catalog::SystemId;
use crate::hw::spec::SystemSpec;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A finish instant with a total order (finish times are never NaN).
#[derive(Clone, Copy, Debug, PartialEq)]
struct FinishAt(f64);

impl Eq for FinishAt {}

impl PartialOrd for FinishAt {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FinishAt {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Dynamic state of one system class (possibly multiple nodes).
#[derive(Clone, Debug)]
pub struct NodeState {
    pub spec: SystemSpec,
    /// next instant each node becomes free (s)
    pub node_free_at: Vec<f64>,
    /// finish instants of assignments not yet completed at the last
    /// `advance_to` time (min-heap)
    inflight: BinaryHeap<Reverse<FinishAt>>,
    /// totals
    pub busy_s: f64,
    pub energy_j: f64,
    pub queries: u64,
}

impl NodeState {
    pub fn new(spec: SystemSpec) -> Self {
        let nodes = spec.count.max(1);
        Self {
            spec,
            node_free_at: vec![0.0; nodes],
            inflight: BinaryHeap::new(),
            busy_s: 0.0,
            energy_j: 0.0,
            queries: 0,
        }
    }

    /// Earliest node availability.
    pub fn earliest_free(&self) -> f64 {
        self.node_free_at.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Retire every assignment that has finished by time `t`, so
    /// [`Self::queue_len`] reflects live state at `t`.
    pub fn advance_to(&mut self, t: f64) {
        while self.inflight.peek().is_some_and(|&Reverse(FinishAt(f))| f <= t) {
            self.inflight.pop();
        }
    }

    /// Queued + in-flight assignments as of the last `advance_to`.
    pub fn queue_len(&self) -> usize {
        self.inflight.len()
    }

    /// Outstanding estimated service seconds at time `t` (for JSQ /
    /// queue-aware cost policies).
    pub fn queue_depth_at(&self, t: f64) -> f64 {
        self.node_free_at.iter().map(|&f| (f - t).max(0.0)).sum()
    }

    /// Schedule a service of `dur` starting no earlier than `t`; returns
    /// (start, finish).
    pub fn schedule(&mut self, t: f64, dur: f64) -> (f64, f64) {
        let (idx, &free_at) = self
            .node_free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .expect("system has nodes");
        let start = t.max(free_at);
        let finish = start + dur;
        self.node_free_at[idx] = finish;
        self.inflight.push(Reverse(FinishAt(finish)));
        self.busy_s += dur;
        self.queries += 1;
        (start, finish)
    }

    /// Schedule one *batch* dispatch starting no earlier than `t`: the
    /// earliest-free node is occupied for `dur` (the whole batch runtime,
    /// amortizing one dispatch), while each member completes at its own
    /// offset from the batch start. Returns the batch start; member
    /// finish instants are `start + member_offsets[k]` (the engine
    /// computes them inline rather than receiving a fresh `Vec` per
    /// dispatch — the batched hot path is allocation-free). This is the
    /// per-class queue discipline: any node of the class may take any
    /// batch. The per-worker-queue engine uses [`Self::schedule_batch_on`]
    /// instead, pinning each virtual worker's batches to its own node.
    pub fn schedule_batch(&mut self, t: f64, dur: f64, member_offsets: &[f64]) -> f64 {
        let (idx, _) = self
            .node_free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .expect("system has nodes");
        self.schedule_batch_on(idx, t, dur, member_offsets)
    }

    /// [`Self::schedule_batch`] pinned to one specific node of the class
    /// — the per-worker-queue engine dispatches each virtual worker's
    /// batches to that worker's own node rather than the class-wide
    /// earliest-free one, so a skewed queue delays only its own node.
    /// The finish heap tracks every member individually so `queue_len`
    /// keeps counting in-flight *queries*, not dispatches.
    pub fn schedule_batch_on(
        &mut self,
        node_idx: usize,
        t: f64,
        dur: f64,
        member_offsets: &[f64],
    ) -> f64 {
        let free_at = self.node_free_at[node_idx];
        let start = t.max(free_at);
        self.node_free_at[node_idx] = start + dur;
        for &off in member_offsets {
            self.inflight.push(Reverse(FinishAt(start + off)));
        }
        self.busy_s += dur;
        self.queries += member_offsets.len() as u64;
        start
    }

    /// Index of the earliest-free node (ties to the lowest index) —
    /// the pick `schedule_batch` makes, exposed so fault-aware
    /// dispatch can consult the fault schedule for that same node
    /// before committing the span.
    pub fn min_free_node(&self) -> usize {
        self.node_free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("system has nodes")
    }

    /// Fault injection: book a dispatch that crashed mid-span. The
    /// node was genuinely busy over `[start_s, crash_s)` and burned
    /// `energy_j` doing work that produced no outcome; it stays
    /// unavailable until `resume_s` (repair completion). No query is
    /// counted — crashed members either retry (and are booked by their
    /// eventual successful attempt) or are abandoned.
    pub fn book_crash_on(
        &mut self,
        node_idx: usize,
        start_s: f64,
        crash_s: f64,
        resume_s: f64,
        energy_j: f64,
    ) {
        self.node_free_at[node_idx] = resume_s;
        self.busy_s += (crash_s - start_s).max(0.0);
        self.energy_j += energy_j;
        // the doomed dispatch still occupies the node until the crash:
        // queue_len sees it in flight over [start, crash)
        self.inflight.push(Reverse(FinishAt(crash_s)));
    }

    /// Continuous-batching support: re-book an in-flight episode on
    /// `node_idx` after a step-boundary admission. The node's free
    /// instant moves to the episode's new projected end, `extra_busy_s`
    /// extends the busy total by the projection delta, and each newly
    /// admitted member registers its finish instant *as projected at
    /// admission* for `queue_len` accounting. Projected finishes are an
    /// approximation: a later admission slows earlier members' steps, so
    /// their heap entries can drain slightly early — episode outcomes
    /// (latency, energy) are computed exactly by the engine and never
    /// read from this heap.
    pub fn extend_batch_on(
        &mut self,
        node_idx: usize,
        new_free_at: f64,
        extra_busy_s: f64,
        member_finishes: &[f64],
    ) {
        self.node_free_at[node_idx] = new_free_at;
        self.busy_s += extra_busy_s;
        self.queries += member_finishes.len() as u64;
        for &f in member_finishes {
            self.inflight.push(Reverse(FinishAt(f)));
        }
    }
}

/// The cluster: all system states, indexable by `SystemId`.
#[derive(Clone, Debug)]
pub struct ClusterState {
    pub nodes: Vec<NodeState>,
}

impl ClusterState {
    pub fn new(systems: &[SystemSpec]) -> Self {
        Self { nodes: systems.iter().cloned().map(NodeState::new).collect() }
    }

    pub fn get(&self, id: SystemId) -> &NodeState {
        &self.nodes[id.0]
    }

    pub fn get_mut(&mut self, id: SystemId) -> &mut NodeState {
        &mut self.nodes[id.0]
    }

    /// Retire finished work cluster-wide (call once per arrival instant).
    pub fn advance_to(&mut self, t: f64) {
        for n in &mut self.nodes {
            n.advance_to(t);
        }
    }

    /// Outstanding seconds per system at time `t`.
    pub fn queue_depths_at(&self, t: f64) -> Vec<f64> {
        self.nodes.iter().map(|n| n.queue_depth_at(t)).collect()
    }

    /// Live in-flight counts per system (as of the last `advance_to`).
    pub fn queue_lens(&self) -> Vec<usize> {
        self.nodes.iter().map(NodeState::queue_len).collect()
    }

    /// Makespan: when the last node finishes.
    pub fn makespan(&self) -> f64 {
        self.nodes
            .iter()
            .flat_map(|n| n.node_free_at.iter().copied())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;

    #[test]
    fn schedule_serializes_on_one_node() {
        let mut specs = system_catalog();
        specs[0].count = 1;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        let (s1, f1) = n.schedule(0.0, 2.0);
        let (s2, f2) = n.schedule(0.0, 3.0);
        assert_eq!((s1, f1), (0.0, 2.0));
        assert_eq!((s2, f2), (2.0, 5.0)); // waits for node
        assert_eq!(n.busy_s, 5.0);
        assert_eq!(n.queries, 2);
    }

    #[test]
    fn multiple_nodes_run_parallel() {
        let mut specs = system_catalog();
        specs[0].count = 2;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        let (_, f1) = n.schedule(0.0, 2.0);
        let (s2, f2) = n.schedule(0.0, 2.0);
        assert_eq!(f1, 2.0);
        assert_eq!(s2, 0.0); // second node picks it up immediately
        assert_eq!(f2, 2.0);
    }

    #[test]
    fn schedule_batch_occupies_node_and_tracks_members() {
        let mut specs = system_catalog();
        specs[0].count = 1;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        // batch of 3: members finish at +1, +2, +4; node busy [0, 4)
        let start = n.schedule_batch(0.0, 4.0, &[1.0, 2.0, 4.0]);
        assert_eq!(start, 0.0);
        assert_eq!(n.queries, 3);
        assert_eq!(n.busy_s, 4.0);
        // queue_len counts members, draining as each finishes at
        // start + offset (1, 2, 4)
        n.advance_to(0.0);
        assert_eq!(n.queue_len(), 3);
        n.advance_to(1.5);
        assert_eq!(n.queue_len(), 2);
        n.advance_to(4.0);
        assert_eq!(n.queue_len(), 0);
        // next batch waits for the node, not for member finishes
        let s2 = n.schedule_batch(1.0, 2.0, &[2.0]);
        assert_eq!(s2, 4.0);
        assert_eq!(n.node_free_at, vec![6.0]);
        // a singleton batch behaves exactly like schedule()
        let mut cs2 = ClusterState::new(&specs);
        let a = cs2.get_mut(SystemId(0));
        let (sa, fa) = a.schedule(3.0, 2.0);
        let mut cs3 = ClusterState::new(&specs);
        let b = cs3.get_mut(SystemId(0));
        let sb = b.schedule_batch(3.0, 2.0, &[2.0]);
        assert_eq!((sa, fa), (sb, sb + 2.0));
        assert_eq!(a.busy_s, b.busy_s);
    }

    #[test]
    fn schedule_batch_on_pins_the_node() {
        let mut specs = system_catalog();
        specs[0].count = 2;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        // occupy node 0; a batch pinned to node 0 must wait for it even
        // though node 1 is idle
        let s0 = n.schedule_batch_on(0, 0.0, 3.0, &[3.0]);
        assert_eq!(s0, 0.0);
        let s1 = n.schedule_batch_on(0, 1.0, 2.0, &[2.0]);
        assert_eq!(s1, 3.0);
        assert_eq!(n.node_free_at, vec![5.0, 0.0]);
        // pinned to the idle node it starts immediately
        let s2 = n.schedule_batch_on(1, 1.0, 2.0, &[2.0]);
        assert_eq!(s2, 1.0);
        assert_eq!(n.queries, 3);
        // with one node, schedule_batch and schedule_batch_on(0) agree
        let mut one = system_catalog();
        one[0].count = 1;
        let mut a = ClusterState::new(&one);
        let mut b = ClusterState::new(&one);
        let ra = a.get_mut(SystemId(0)).schedule_batch(2.0, 4.0, &[1.0, 4.0]);
        let rb = b.get_mut(SystemId(0)).schedule_batch_on(0, 2.0, 4.0, &[1.0, 4.0]);
        assert_eq!(ra, rb);
        assert_eq!(a.node_free_at, b.node_free_at);
    }

    #[test]
    fn book_crash_on_occupies_until_repair() {
        let mut specs = system_catalog();
        specs[0].count = 2;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        assert_eq!(n.min_free_node(), 0, "ties break to the lowest index");
        // dispatch at t=1 crashes at t=3; node 0 repairs at t=10
        n.book_crash_on(0, 1.0, 3.0, 10.0, 5.0);
        assert_eq!(n.node_free_at, vec![10.0, 0.0]);
        assert_eq!(n.min_free_node(), 1);
        assert!((n.busy_s - 2.0).abs() < 1e-12);
        assert!((n.energy_j - 5.0).abs() < 1e-12);
        assert_eq!(n.queries, 0, "crashed work serves no query");
        n.advance_to(2.0);
        assert_eq!(n.queue_len(), 1, "doomed dispatch is in flight until the crash");
        n.advance_to(3.0);
        assert_eq!(n.queue_len(), 0);
    }

    #[test]
    fn makespan_is_max_over_nodes() {
        let specs = system_catalog();
        let mut cs = ClusterState::new(&specs);
        cs.get_mut(SystemId(0)).schedule(0.0, 5.0);
        cs.get_mut(SystemId(1)).schedule(0.0, 9.0);
        assert_eq!(cs.makespan(), 9.0);
    }

    #[test]
    fn queue_state_drains_as_time_advances() {
        let mut specs = system_catalog();
        specs[0].count = 1;
        let mut cs = ClusterState::new(&specs);
        let n = cs.get_mut(SystemId(0));
        n.schedule(0.0, 2.0); // busy [0, 2)
        n.schedule(0.0, 3.0); // busy [2, 5)
        n.advance_to(0.0);
        assert_eq!(n.queue_len(), 2);
        assert!((n.queue_depth_at(0.0) - 5.0).abs() < 1e-12);
        n.advance_to(2.0);
        assert_eq!(n.queue_len(), 1); // first finished exactly at t=2
        assert!((n.queue_depth_at(3.0) - 2.0).abs() < 1e-12);
        n.advance_to(5.0);
        assert_eq!(n.queue_len(), 0);
        assert_eq!(n.queue_depth_at(10.0), 0.0);
    }

    #[test]
    fn cluster_advance_applies_to_every_system() {
        let specs = system_catalog();
        let mut cs = ClusterState::new(&specs);
        cs.get_mut(SystemId(0)).schedule(0.0, 1.0);
        cs.get_mut(SystemId(1)).schedule(0.0, 4.0);
        cs.advance_to(0.0);
        assert_eq!(cs.queue_lens(), vec![1, 1, 0]);
        cs.advance_to(2.0);
        assert_eq!(cs.queue_lens(), vec![0, 1, 0]);
        let depths = cs.queue_depths_at(2.0);
        assert_eq!(depths[0], 0.0);
        assert!((depths[1] - 2.0).abs() < 1e-12);
        cs.advance_to(100.0);
        assert_eq!(cs.queue_lens(), vec![0, 0, 0]);
    }
}
