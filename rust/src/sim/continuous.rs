//! Iteration-level (continuous) batching episodes — the sim-side state
//! behind `BatchingOptions::mode = Continuous` (Orca/vLLM-style
//! scheduling, the regime the static batch model predates).
//!
//! An **episode** is one node's uninterrupted run of merged decoding:
//! it is *founded* by an ordinary static dispatch (same formation, same
//! joint-KV trim, same memoized [`BatchCost`]), and then — at **step
//! boundaries** only — admits waiting queries into its live set and
//! retires members at their own `n`. The decode timeline is priced by
//! [`PerfModel::decode_span_time`]: weights stream once per step across
//! the current live set, each segment chained onto the accumulator so
//! segment splits never change the float result.
//!
//! Live-set invariants:
//! - `live` is sorted by retire step (stable on ties), so `live[0]`
//!   always carries the next boundary and every decode segment sums
//!   members in the same order [`PerfModel::batch_cost`] uses for its
//!   retirement suffixes — an episode that never admits anyone replays
//!   the founding batch's closed-form cost **bit-identically** (tested
//!   below, and finalized straight from `founding_cost` in the engine).
//! - members are admitted at their full `(m, n)` footprint
//!   ([`crate::sched::admission`]), so no admission can OOM the set
//!   later in its own decode.
//! - admissions happen only when `live` shrinks strictly below the
//!   configured cap, and only at boundaries — never mid-step.
//!
//! Episode **energy** uses the same three-phase construction as
//! [`PerfModel::batch_cost`] (overhead at 5% util, prefill, decode):
//! phase energy is a duration-weighted sum, so merging each kind of
//! phase into one is exact no matter how admissions interleaved them.

use crate::hw::power::{Phase, PowerModel};
use crate::hw::spec::SystemSpec;
use crate::perf::energy::Attribution;
use crate::perf::model::{BatchCost, PerfModel};
use std::sync::Arc;

/// One member currently decoding in an episode.
#[derive(Clone, Copy, Debug)]
pub struct LiveMember {
    /// trace index
    pub qi: usize,
    pub m: u32,
    pub n: u32,
    /// absolute decode step at which the member joined the live set
    /// (0 for founding members)
    pub joined: u64,
    /// wall-clock instant service began: episode start for founders,
    /// the admission boundary for step-boundary admissions
    pub admit_s: f64,
}

impl LiveMember {
    /// The absolute decode step at which this member retires.
    pub fn retire_step(&self) -> u64 {
        self.joined + self.n as u64
    }
}

/// A member that has retired from the live set.
#[derive(Clone, Copy, Debug)]
pub struct RetiredMember {
    pub qi: usize,
    pub m: u32,
    pub n: u32,
    pub admit_s: f64,
    /// finish offset from episode start: overhead + prefill + decode
    /// seconds accumulated at the member's retirement boundary
    pub finish_rel: f64,
}

/// One node's continuous-batching run: founded by a static dispatch,
/// admitting at step boundaries, retiring members at their own `n`.
/// Owned by the batched engines as `episodes[system][node]`.
#[derive(Clone, Debug)]
pub struct Episode {
    /// node index within the system class
    pub node: usize,
    /// wall-clock instant the founding batch started
    pub start_s: f64,
    /// accumulated dispatch-overhead seconds: one per founding plus one
    /// per admission *event* (a boundary admitting k members pays one
    /// dispatch, exactly like a k-member batch)
    pub overhead_s: f64,
    /// accumulated prefill seconds across every member admitted so far
    pub prefill_s: f64,
    /// chained decode-span accumulator (seconds completed so far)
    pub decode_s: f64,
    /// decode steps completed so far
    pub step: u64,
    /// currently decoding members, sorted by retire step (stable)
    pub live: Vec<LiveMember>,
    /// retired members with their exact finish offsets
    pub done: Vec<RetiredMember>,
    /// whether any step-boundary admission has happened — when false the
    /// episode finalizes straight from `founding_cost`, bit-identical to
    /// the static dispatch it started as
    pub admitted_any: bool,
    /// founding members `(qi, m, n)` in selection order (the order
    /// `founding_cost.member_finish_s` is indexed by)
    pub founding: Vec<(usize, u32, u32)>,
    /// the founding batch's memoized static cost
    pub founding_cost: Arc<BatchCost>,
    /// wall-clock instant of the next step-boundary event (the earliest
    /// live retirement); refreshed after every boundary and admission
    pub next_boundary_s: f64,
    /// runtime currently booked on the node (founding runtime at
    /// creation, the latest projection after an admission)
    pub booked_runtime_s: f64,
    /// energy currently booked on the node
    pub booked_energy_j: f64,
}

impl Episode {
    /// Found an episode from a static dispatch: `members` are
    /// `(qi, m, n)` in selection order, `cost` their memoized batch
    /// cost, `start_s` the batch start the node booked. The live set is
    /// re-sorted by ascending `n` (stable), matching `batch_cost`'s
    /// retirement order. The caller refreshes `next_boundary_s` before
    /// relying on it.
    pub fn found(
        node: usize,
        start_s: f64,
        members: &[(usize, u32, u32)],
        cost: Arc<BatchCost>,
        booked_energy_j: f64,
    ) -> Self {
        let mut live: Vec<LiveMember> = members
            .iter()
            .map(|&(qi, m, n)| LiveMember { qi, m, n, joined: 0, admit_s: start_s })
            .collect();
        live.sort_by_key(|lm| lm.n);
        Self {
            node,
            start_s,
            overhead_s: cost.overhead_s,
            prefill_s: cost.prefill_s,
            decode_s: 0.0,
            step: 0,
            live,
            done: Vec::new(),
            admitted_any: false,
            founding: members.to_vec(),
            booked_runtime_s: cost.runtime_s,
            founding_cost: cost,
            next_boundary_s: f64::INFINITY,
            booked_energy_j,
        }
    }

    /// Advance decode through the next retirement boundary: extend the
    /// chained span accumulator to `live[0]`'s retire step and move
    /// every member retiring there from `live` to `done` (recording
    /// exact finish offsets). Returns how many retired. The caller
    /// admits/refreshes/finalizes afterwards. `pairs` is reusable
    /// scratch for the `(m, joined)` live view.
    pub fn advance_retirement(
        &mut self,
        perf: &PerfModel,
        spec: &SystemSpec,
        pairs: &mut Vec<(u32, u64)>,
    ) -> usize {
        let end = self.live[0].retire_step();
        pairs.clear();
        pairs.extend(self.live.iter().map(|lm| (lm.m, lm.joined)));
        self.decode_s = perf.decode_span_time(spec, pairs, self.step, end, self.decode_s);
        self.step = end;
        let mut retired = 0;
        while !self.live.is_empty() && self.live[0].retire_step() <= self.step {
            let lm = self.live.remove(0);
            self.done.push(RetiredMember {
                qi: lm.qi,
                m: lm.m,
                n: lm.n,
                admit_s: lm.admit_s,
                finish_rel: self.overhead_s + self.prefill_s + self.decode_s,
            });
            retired += 1;
        }
        retired
    }

    /// Insert an admitted member, keeping `live` sorted by retire step
    /// (stable: ties go after existing members) and marking the episode
    /// as admission-bearing.
    pub fn admit(&mut self, member: LiveMember) {
        let pos = self.live.partition_point(|x| x.retire_step() <= member.retire_step());
        self.live.insert(pos, member);
        self.admitted_any = true;
    }

    /// Recompute `next_boundary_s` by previewing the next decode segment
    /// — the same chained [`PerfModel::decode_span_time`] call the
    /// matching [`Self::advance_retirement`] will make, so the boundary
    /// instant and the advance land on identical floats. Requires a
    /// non-empty live set.
    pub fn refresh_next_boundary(
        &mut self,
        perf: &PerfModel,
        spec: &SystemSpec,
        pairs: &mut Vec<(u32, u64)>,
    ) {
        let end = self.live[0].retire_step();
        pairs.clear();
        pairs.extend(self.live.iter().map(|lm| (lm.m, lm.joined)));
        let d = perf.decode_span_time(spec, pairs, self.step, end, self.decode_s);
        self.next_boundary_s = self.start_s + self.overhead_s + self.prefill_s + d;
    }

    /// Project the remaining decode assuming no further admissions:
    /// chained spans over the retirement segments of the current live
    /// set — exactly the spans later [`Self::advance_retirement`] calls
    /// will accumulate, so if no admission intervenes the projection is
    /// bit-identical to what actually happens. Returns total decode
    /// seconds at episode end; `finish_rel[i]` gets `live[i]`'s
    /// projected finish offset (under the *current* overhead/prefill
    /// totals).
    pub fn project_decode(
        &self,
        perf: &PerfModel,
        spec: &SystemSpec,
        pairs: &mut Vec<(u32, u64)>,
        finish_rel: &mut Vec<f64>,
    ) -> f64 {
        finish_rel.clear();
        finish_rel.resize(self.live.len(), 0.0);
        let mut acc = self.decode_s;
        let mut step = self.step;
        let mut i = 0;
        while i < self.live.len() {
            let end = self.live[i].retire_step();
            pairs.clear();
            pairs.extend(self.live[i..].iter().map(|lm| (lm.m, lm.joined)));
            acc = perf.decode_span_time(spec, pairs, step, end, acc);
            step = end;
            while i < self.live.len() && self.live[i].retire_step() <= step {
                finish_rel[i] = self.overhead_s + self.prefill_s + acc;
                i += 1;
            }
        }
        acc
    }

    /// Σ `(m + n)` over everyone ever in the episode (token-share
    /// denominator for energy attribution), summed in retirement order
    /// then live order — deterministic.
    pub fn total_tokens(&self) -> f64 {
        let done: f64 = self.done.iter().map(|d| (d.m + d.n) as f64).sum();
        let live: f64 = self.live.iter().map(|l| (l.m + l.n) as f64).sum();
        done + live
    }
}

/// Episode energy through the same phase construction as
/// [`PerfModel::batch_cost`]: one merged overhead phase at 5% util, one
/// merged prefill phase, one merged decode phase. Phase energy is
/// `power(util) × duration` summed over phases, so merging every phase
/// of a kind is exact regardless of how admissions interleaved them —
/// and an episode whose durations equal a static batch's has exactly
/// that batch's energy (tested below).
pub fn episode_energy(
    spec: &SystemSpec,
    overhead_s: f64,
    prefill_s: f64,
    decode_s: f64,
    attribution: Attribution,
) -> f64 {
    let mut phases = Vec::with_capacity(3);
    if overhead_s > 0.0 {
        phases.push(Phase { dur_s: overhead_s, util: 0.05, host_active: true });
    }
    if prefill_s > 0.0 {
        phases.push(Phase { dur_s: prefill_s, util: spec.util_prefill, host_active: true });
    }
    if decode_s > 0.0 {
        phases.push(Phase { dur_s: decode_s, util: spec.util_decode, host_active: true });
    }
    let pm = PowerModel { phases };
    match attribution {
        Attribution::Total => pm.total_energy(spec),
        Attribution::Net => pm.net_energy(spec),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::{system_catalog, SystemId};
    use crate::model::llm_catalog;

    fn perf() -> PerfModel {
        PerfModel::new(llm_catalog()[1].clone())
    }

    fn founded(members: &[(usize, u32, u32)], perf: &PerfModel, spec: &SystemSpec) -> Episode {
        let pairs: Vec<(u32, u32)> = members.iter().map(|&(_, m, n)| (m, n)).collect();
        let cost = Arc::new(perf.batch_cost(spec, &pairs));
        assert!(cost.is_feasible());
        Episode::found(0, 0.0, members, cost, 0.0)
    }

    /// An episode that never admits anyone replays the founding batch's
    /// closed-form decode and per-member finishes bit-for-bit: same
    /// segment ends, same suffix order, same chained accumulator.
    #[test]
    fn admissionless_episode_replays_batch_cost_bitwise() {
        let p = perf();
        let spec = &system_catalog()[SystemId::SWING_A100.0];
        let members = [(0usize, 32u32, 8u32), (1, 300, 64), (2, 64, 8), (3, 128, 200)];
        let mut ep = founded(&members, &p, spec);
        let cost = Arc::clone(&ep.founding_cost);
        let mut pairs = Vec::new();
        while !ep.live.is_empty() {
            ep.refresh_next_boundary(&p, spec, &mut pairs);
            let before = ep.next_boundary_s;
            ep.advance_retirement(&p, spec, &mut pairs);
            // the boundary preview and the advance land on the same floats
            let at = ep.start_s + ep.overhead_s + ep.prefill_s + ep.decode_s;
            assert_eq!(before.to_bits(), at.to_bits());
        }
        assert!(!ep.admitted_any);
        assert_eq!(ep.decode_s.to_bits(), cost.decode_s.to_bits());
        // every member's episode finish offset == batch_cost's
        for d in &ep.done {
            let k = members.iter().position(|&(qi, _, _)| qi == d.qi).unwrap();
            assert_eq!(
                d.finish_rel.to_bits(),
                cost.member_finish_s[k].to_bits(),
                "member {k} finish mismatch"
            );
        }
        // and the merged-phase energy equals the batch's
        let e = episode_energy(spec, ep.overhead_s, ep.prefill_s, ep.decode_s, Attribution::Total);
        assert_eq!(e.to_bits(), cost.energy_j.to_bits());
        let net = episode_energy(spec, ep.overhead_s, ep.prefill_s, ep.decode_s, Attribution::Net);
        assert_eq!(net.to_bits(), cost.net_energy_j.to_bits());
    }

    /// `project_decode` is a faithful preview: advancing boundary by
    /// boundary lands on exactly the projected totals and finishes when
    /// no admission intervenes — the property the engine's node
    /// re-booking depends on.
    #[test]
    fn projection_matches_actual_advance_bitwise() {
        let p = perf();
        let spec = &system_catalog()[SystemId::SWING_A100.0];
        let members = [(0usize, 64u32, 16u32), (1, 200, 120), (2, 32, 48)];
        let mut ep = founded(&members, &p, spec);
        // stir in one admission so the replayed path is the general one
        let mut pairs = Vec::new();
        ep.refresh_next_boundary(&p, spec, &mut pairs);
        ep.advance_retirement(&p, spec, &mut pairs);
        ep.overhead_s += spec.overhead_s;
        ep.prefill_s += p.prefill_time(spec, 80);
        ep.admit(LiveMember { qi: 9, m: 80, n: 64, joined: ep.step, admit_s: ep.next_boundary_s });

        let mut finish = Vec::new();
        let projected_decode = ep.project_decode(&p, spec, &mut pairs, &mut finish);
        let projected: Vec<(usize, u64)> =
            ep.live.iter().zip(&finish).map(|(lm, f)| (lm.qi, f.to_bits())).collect();

        while !ep.live.is_empty() {
            ep.advance_retirement(&p, spec, &mut pairs);
        }
        assert_eq!(ep.decode_s.to_bits(), projected_decode.to_bits());
        for (qi, fbits) in projected {
            let d = ep.done.iter().find(|d| d.qi == qi).unwrap();
            assert_eq!(d.finish_rel.to_bits(), fbits, "member {qi} projected finish drifted");
        }
    }

    /// Admission keeps the live set sorted by retire step and joint
    /// decoding of the merged set is cheaper than two separate tails —
    /// the weight stream is shared.
    #[test]
    fn admitted_member_sorts_by_retire_step_and_merging_saves_decode() {
        let p = perf();
        let spec = &system_catalog()[SystemId::SWING_A100.0];
        let members = [(0usize, 64u32, 100u32), (1, 64, 200)];
        let mut ep = founded(&members, &p, spec);
        ep.admit(LiveMember { qi: 2, m: 64, n: 100, joined: 50, admit_s: 1.0 });
        let steps: Vec<u64> = ep.live.iter().map(LiveMember::retire_step).collect();
        assert_eq!(steps, vec![100, 150, 200]);
        assert!(ep.admitted_any);

        // merged decode of the two overlapping members over [50, 100)
        let mut pairs = Vec::new();
        pairs.extend([(64u32, 0u64), (64, 50)]);
        let merged = p.decode_span_time(spec, &pairs, 50, 100, 0.0);
        let alone_a = p.decode_span_time(spec, &[(64, 0)], 50, 100, 0.0);
        let alone_b = p.decode_span_time(spec, &[(64, 50)], 50, 100, 0.0);
        assert!(
            merged < alone_a + alone_b,
            "merged {merged} should undercut separate {}",
            alone_a + alone_b
        );
    }

    #[test]
    fn total_tokens_counts_done_and_live() {
        let p = perf();
        let spec = &system_catalog()[SystemId::SWING_A100.0];
        let members = [(0usize, 10u32, 5u32), (1, 20, 8)];
        let mut ep = founded(&members, &p, spec);
        assert_eq!(ep.total_tokens(), 43.0);
        let mut pairs = Vec::new();
        ep.advance_retirement(&p, spec, &mut pairs);
        assert_eq!(ep.total_tokens(), 43.0);
        ep.admit(LiveMember { qi: 5, m: 7, n: 3, joined: ep.step, admit_s: 0.5 });
        assert_eq!(ep.total_tokens(), 53.0);
    }
}
