//! The simulation engine: trace × policy × cluster → SimReport.
//!
//! Three modes:
//! - **batch** (the paper's Eq. 9/10 analysis): assignments don't
//!   interact; each query is charged its standalone `R`/`E` and nodes
//!   serialize FIFO per system. Arrivals are all at t=0.
//! - **online**: queries arrive over time; the policy sees live queue
//!   state (enabling queue-aware extensions the paper speculates about).
//!   Queue state is derived from `node_free_at` at each arrival instant
//!   — both `queue_depth_s` and `queue_len` drain as work completes.
//! - **batched online** ([`SimOptions::batching`]): the virtual-time
//!   mirror of the serving coordinator's dynamic batcher
//!   (`coordinator::batcher::SystemQueue::take_batch_with`). Routed
//!   queries queue per **virtual worker** — by default one queue per
//!   node ([`QueueModel::PerWorker`]), matching the coordinator's
//!   one-worker-thread-per-node cadence so batch formation interacts
//!   with multi-node skew; [`QueueModel::PerClass`] keeps the older
//!   one-queue-per-system-class layout, which matches the coordinator's
//!   shared-queue membership semantics (see [`QueueModel`] for how the
//!   two bracket a real deployment). A queue's batch
//!   becomes due the moment `max_batch` members are waiting, or after
//!   lingering `linger_s` from when its node could first take the batch
//!   — and when the shared
//!   [`crate::sched::formation::FormationPolicy`] looks past one batch,
//!   its *membership* is decided at hand-off (when the node is free to
//!   take it), exactly as workers calling `take_batch` do. Batch costs
//!   follow the batched
//!   `R`/`E` extension (Wilkins et al., arXiv 2407.04014) via
//!   [`crate::perf::model::PerfModel::batch_cost`]. With `max_batch = 1`
//!   this mode is bit-identical to plain online simulation, and on
//!   single-node classes the two queue layouts are bit-identical to
//!   each other (both pinned by property tests).
//!
//! The batched engine is **event-driven**: instead of re-scanning every
//! virtual queue per step to find the earliest due batch (O(Σ queues)
//! per dispatch), it keeps one lazily invalidated
//! [`std::collections::BinaryHeap`] of per-queue due events, so each
//! step costs O(log #queues). Due times are strictly queue-local (a
//! dispatch moves only its own queue's node availability; an arrival
//! changes only the queue it joins), so exactly one event is recomputed
//! per step. The retained scan loop
//! (`simulate_batched_with_tables_scan`) and the PR-4 allocating loop
//! (`simulate_batched_with_tables_reference`) pin the heap engine
//! bit-identical across seeds, policies, queue models, and formation
//! policies.
//!
//! Per-query costs come from a [`CostTable`] built once per trace
//! ([`simulate`] builds it; [`simulate_with_table`] reuses a shared one
//! across a sweep grid — see [`crate::experiments::runner`]); batch
//! costs come from a composition-memoized [`BatchTable`].
//!
//! Infeasible assignments (policy sent an OOM query somewhere) panic in
//! [`SimOptions::strict`] mode; otherwise they are re-routed to the
//! cheapest feasible system and counted in [`SimReport::rerouted`].
//! Arrival-sortedness is a hard `assert!` even in release builds: an
//! unsorted trace silently corrupts every queue view, and the O(n) scan
//! is noise next to the simulation itself.

use super::cluster::{ClusterState, NodeState};
use super::continuous::{episode_energy, Episode, LiveMember};
use super::report::{BatchStats, QueryOutcome, ShedLedger, ShedStats, SimReport, SystemTotals};
use crate::hw::catalog::SystemId;
use crate::hw::spec::SystemSpec;
use crate::perf::cost_table::{BatchTable, CostTable};
use crate::perf::energy::EnergyModel;
use crate::perf::model::Feasibility;
use crate::sched::admission;
use crate::sched::faults::{FaultConfig, FaultState, RetryAttempt};
use crate::sched::formation::{FormationPolicy, FormationScratch, SortedWindow};
use crate::sched::overload::{AdmissionConfig, AdmitDecision, OverloadPolicy};
use crate::sched::policy::{ClusterView, Policy};
use crate::workload::Query;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};
use std::sync::Arc;

/// Which virtual queue layout the batched engine simulates.
///
/// The serving coordinator spawns one worker thread per *node*
/// (`SystemSpec::count` workers per class), each calling `take_batch`
/// when it frees up — against **one shared class queue**, so batch
/// membership is decided by whichever worker frees first.
/// [`QueueModel::PerWorker`] instead gives every node its own virtual
/// queue: a newly routed query is assigned to the least-loaded queue of
/// its system at arrival, batches form per queue at that node's own
/// cadence, and a skewed queue delays only its own node — which is what
/// lets formation policies interact with multi-node skew (and what a
/// queue-per-replica sharded deployment does). [`QueueModel::PerClass`]
/// keeps the earlier layout — one queue feeding `count` interchangeable
/// nodes — which matches the coordinator's shared-queue *membership*
/// semantics. Neither is the serving path exactly (PerWorker has no
/// work stealing between sibling queues; PerClass forms only one batch
/// per class at a time): the two bracket a real multi-node deployment,
/// and on single-node classes — where the distinction vanishes — they
/// are bit-identical to each other and to the coordinator-equivalence
/// suite in `rust/tests/formation_sim.rs` (property-tested).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum QueueModel {
    /// one virtual queue per node (default: per-node cadence, the
    /// fleet-study axis)
    #[default]
    PerWorker,
    /// one queue per system class, any node takes the next batch (the
    /// coordinator's shared-queue membership semantics)
    PerClass,
}

impl QueueModel {
    /// Canonical spelling (used by reports and config files).
    pub fn name(&self) -> &'static str {
        match self {
            QueueModel::PerWorker => "per-worker",
            QueueModel::PerClass => "per-class",
        }
    }

    /// Parse a CLI/config spelling: `per-worker` or `per-class`.
    pub fn parse(s: &str) -> Result<QueueModel, String> {
        match s {
            "per-worker" | "worker" => Ok(QueueModel::PerWorker),
            "per-class" | "class" => Ok(QueueModel::PerClass),
            other => {
                Err(format!("unknown queue model '{other}' (expected per-worker | per-class)"))
            }
        }
    }
}

/// Static (batch-atomic) vs continuous (iteration-level) dispatch.
///
/// `Static` is the historical regime: a batch decodes at its longest
/// member's pace and admits nobody until it retires. `Continuous` is
/// the Orca/vLLM-style regime: a dispatch *founds* an episode whose
/// members retire at their own `n`, and waiting queries join the live
/// set at decode-step boundaries (FIFO prefix, joint-KV checked —
/// [`crate::sched::admission`]). Continuous requires `max_batch > 1`:
/// with `max_batch = 1` (or admission frozen) the engine runs the
/// static path wholesale, which is what keeps the `max_batch = 1` ≡
/// serial and frozen ≡ static bit-identity properties true by
/// construction rather than by float coincidence.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// batch = atomic dispatch unit (the paper-era model)
    #[default]
    Static,
    /// decode step = scheduling unit; members join at step boundaries
    Continuous {
        /// live-set size cap; 0 means "use `max_batch`"
        max_live: usize,
    },
}

impl BatchMode {
    /// Canonical spelling (used by reports and config files).
    pub fn name(&self) -> &'static str {
        match self {
            BatchMode::Static => "static",
            BatchMode::Continuous { .. } => "continuous",
        }
    }
}

/// Dynamic-batching knobs for the simulator — the virtual-time analogue
/// of the coordinator's `(max_batch, max_wait)` pair, plus the shared
/// batch-formation policy ([`crate::sched::formation`]), the virtual
/// queue layout ([`QueueModel`]), and the dispatch mode ([`BatchMode`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchingOptions {
    /// dispatch as soon as this many queries are waiting (≥ 1)
    pub max_batch: usize,
    /// how long a partial batch lingers for stragglers before
    /// dispatching, counted from the instant a node could first take it
    pub linger_s: f64,
    /// which waiting requests form each batch — FIFO prefixes, or
    /// shape-aware grouping of near-equal output lengths
    pub formation: FormationPolicy,
    /// one virtual queue per node (default) or per system class
    pub queues: QueueModel,
    /// static (batch-atomic) or continuous (iteration-level) dispatch
    pub mode: BatchMode,
    /// per-dispatch overhead in straggler-step units for the costed
    /// `ShapeAware` window DP ([`crate::sched::formation`]): a split is
    /// taken only when the drag it removes exceeds this. 0 (default)
    /// keeps the historical drag-only objective bit-identically.
    pub dispatch_cost_steps: u64,
    /// bound on the batch-cost memo the engine builds its [`BatchTable`]
    /// with (total cached entries across shards, clock-evicted); 0
    /// (default) keeps the memo unbounded
    pub memo_capacity: usize,
    /// test hook: run continuous mode with admission frozen at dispatch
    /// — behaviorally the static engine (property-pinned bit-identical)
    #[doc(hidden)]
    pub freeze_admission: bool,
}

impl BatchingOptions {
    /// FIFO-prefix, per-worker-queue, static batching with the given
    /// knobs.
    pub fn new(max_batch: usize, linger_s: f64) -> Self {
        Self {
            max_batch,
            linger_s,
            formation: FormationPolicy::FifoPrefix,
            queues: QueueModel::PerWorker,
            mode: BatchMode::Static,
            dispatch_cost_steps: 0,
            memo_capacity: 0,
            freeze_admission: false,
        }
    }

    pub fn with_formation(mut self, formation: FormationPolicy) -> Self {
        self.formation = formation;
        self
    }

    pub fn with_queues(mut self, queues: QueueModel) -> Self {
        self.queues = queues;
        self
    }

    /// Iteration-level (continuous) batching with the given live-set
    /// cap (0 = cap at `max_batch`).
    pub fn with_continuous(mut self, max_live: usize) -> Self {
        self.mode = BatchMode::Continuous { max_live };
        self
    }

    /// Per-dispatch overhead (straggler-step units) folded into the
    /// shape-aware formation objective.
    pub fn with_dispatch_cost(mut self, steps: u64) -> Self {
        self.dispatch_cost_steps = steps;
        self
    }

    /// Bound the engine-built batch-cost memo (0 = unbounded).
    pub fn with_memo_capacity(mut self, capacity: usize) -> Self {
        self.memo_capacity = capacity;
        self
    }

    /// Continuous mode with admission frozen at dispatch — the
    /// degenerate case the property suite pins bit-identical to static.
    #[doc(hidden)]
    pub fn with_frozen_admission(mut self) -> Self {
        self.freeze_admission = true;
        self
    }
}

/// Engine knobs.
///
/// ```
/// use hetsched::config::schema::PolicyConfig;
/// use hetsched::hw::catalog::system_catalog;
/// use hetsched::model::llm_catalog;
/// use hetsched::perf::energy::EnergyModel;
/// use hetsched::perf::model::PerfModel;
/// use hetsched::sched::policy::build_policy;
/// use hetsched::sim::engine::{simulate, BatchingOptions, SimOptions};
/// use hetsched::workload::Query;
///
/// let systems = system_catalog();
/// let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
/// let queries = vec![Query::new(0, 32, 16), Query::new(1, 300, 64)];
/// let mut policy = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, energy.clone(), &systems);
///
/// // serial online simulation, charging the idle floor across the makespan
/// let opts = SimOptions { include_idle_energy: true, ..Default::default() };
/// let report = simulate(&queries, &systems, policy.as_mut(), &energy, &opts);
/// assert_eq!(report.outcomes.len(), 2);
/// assert!(report.idle_energy_j > 0.0);
///
/// // batched online mode: per-worker queues, up to 8 queries per dispatch
/// let batched = SimOptions {
///     batching: Some(BatchingOptions::new(8, 0.25)),
///     ..Default::default()
/// };
/// let mut policy = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, energy.clone(), &systems);
/// let report = simulate(&queries, &systems, policy.as_mut(), &energy, &batched);
/// assert!(report.energy_conserved());
/// ```
#[derive(Clone, Debug, Default)]
pub struct SimOptions {
    /// charge idle-floor energy of all nodes across the makespan
    pub include_idle_energy: bool,
    /// panic if the policy picks an infeasible system (tests); otherwise
    /// fall back to the cheapest feasible one and count it in
    /// [`SimReport::rerouted`]
    pub strict: bool,
    /// `Some` enables batched online mode (see module docs)
    pub batching: Option<BatchingOptions>,
    /// `Some` enables SLO-aware admission and per-tenant load shedding
    /// — the shared [`crate::sched::overload`] policy, identical to the
    /// serving coordinator's. `None` runs the historical
    /// admit-everything path byte-for-byte (property-pinned).
    pub admission: Option<AdmissionConfig>,
    /// `Some` (and [`FaultConfig::enabled`]) injects the shared
    /// deterministic fault schedule ([`crate::sched::faults`]): node
    /// crashes requeue in-flight work through the retry/backoff policy,
    /// slowdowns stretch runtime and energy, and the report gains
    /// per-system retry counts plus wasted (crashed-attempt) joules.
    /// `None` — or a disabled config — runs the historical fault-free
    /// engines byte-for-byte (property-pinned in
    /// `rust/tests/fault_properties.rs`).
    pub faults: Option<FaultConfig>,
}

/// Whether this run actually injects faults — `Some` with a config that
/// enables crashes or slowdowns. A disabled config is treated exactly
/// like an absent one (the fault-free engines run unchanged).
pub(crate) fn faults_live(opts: &SimOptions) -> bool {
    opts.faults.as_ref().is_some_and(FaultConfig::enabled)
}

/// Run the simulation, evaluating the perf/energy model through a
/// freshly built [`CostTable`]. Queries must be sorted by arrival time
/// (batch traces trivially are). With [`SimOptions::batching`] set this
/// also builds a [`BatchTable`] and runs the batched engine.
pub fn simulate(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    energy: &EnergyModel,
    opts: &SimOptions,
) -> SimReport {
    let table = CostTable::build(queries, systems, energy);
    if let Some(bopts) = &opts.batching {
        let batch_table =
            BatchTable::new(energy.clone(), systems).with_capacity(bopts.memo_capacity);
        simulate_batched_with_tables(queries, systems, policy, &table, &batch_table, opts)
    } else {
        simulate_with_table(queries, systems, policy, &table, opts)
    }
}

/// Hard release-mode guard: an unsorted trace makes every derived queue
/// view garbage, so refuse to simulate one.
fn assert_sorted(queries: &[Query]) {
    assert!(
        queries.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
        "queries must be sorted by arrival time"
    );
}

/// Policy assignment + feasibility fallback, shared verbatim by the
/// serial and batched engines so their routing is identical.
fn route_query(
    policy: &mut dyn Policy,
    q: &Query,
    qi: usize,
    view: &ClusterView,
    table: &CostTable,
    systems: &[SystemSpec],
    strict: bool,
    rerouted: &mut u64,
) -> SystemId {
    let (m, n) = (q.input_tokens, q.output_tokens);
    let mut sid = policy.assign(q, view);
    assert!(sid.0 < systems.len(), "policy returned out-of-range system");
    if table.feasibility(qi, sid.0) != Feasibility::Ok {
        if strict {
            panic!(
                "policy '{}' routed infeasible query (m={m}, n={n}) to {}",
                policy.name(),
                systems[sid.0].name
            );
        }
        // fall back: cheapest feasible system
        sid = SystemId(
            table
                .cheapest_feasible(qi)
                .unwrap_or_else(|| panic!("query (m={m},n={n}) feasible nowhere")),
        );
        *rerouted += 1;
    }
    sid
}

/// Makespan/idle accounting + report assembly, shared by both engines.
fn finalize_report(
    policy_name: String,
    cluster: &ClusterState,
    outcomes: Vec<QueryOutcome>,
    opts: &SimOptions,
    rerouted: u64,
    batches: Vec<BatchStats>,
    serial_energy_j: f64,
    shed: Vec<ShedStats>,
) -> SimReport {
    let makespan = cluster.makespan();
    let idle_energy: f64 = if opts.include_idle_energy {
        cluster
            .nodes
            .iter()
            .map(|node| {
                let spec = &node.spec;
                let capacity_s = makespan * spec.count as f64;
                // busy seconds beyond node capacity would mean the
                // scheduler double-booked a node; surface it in debug
                // builds instead of letting the clamp silently absorb it
                debug_assert!(
                    node.busy_s <= capacity_s + 1e-9 * capacity_s.max(1.0),
                    "{}: busy_s {} exceeds makespan × count = {} — scheduling accounting bug",
                    spec.name,
                    node.busy_s,
                    capacity_s
                );
                spec.idle_w * (capacity_s - node.busy_s).max(0.0)
            })
            .sum()
    } else {
        0.0
    };

    let total_service: f64 = outcomes.iter().map(|o| o.service_s).sum();
    let total_energy: f64 =
        cluster.nodes.iter().map(|n| n.energy_j).sum::<f64>() + idle_energy;

    SimReport {
        policy: policy_name,
        systems: cluster
            .nodes
            .iter()
            .map(|n| SystemTotals {
                name: n.spec.name.to_string(),
                queries: n.queries,
                busy_s: n.busy_s,
                energy_j: n.energy_j,
            })
            .collect(),
        outcomes,
        makespan_s: makespan,
        total_service_s: total_service,
        total_energy_j: total_energy,
        idle_energy_j: idle_energy,
        rerouted,
        batches,
        serial_energy_j,
        shed,
        retries: vec![0; cluster.nodes.len()],
        wasted_energy_j: 0.0,
    }
}

/// Run the simulation against a prebuilt [`CostTable`] (row `i` must
/// describe `queries[i]` over exactly `systems`). Sweeps that replay the
/// same trace under many policies / grid points build the table once and
/// call this per point. Serial dispatch only — use
/// [`simulate_batched_with_tables`] (or [`simulate`]) when
/// [`SimOptions::batching`] is set.
pub fn simulate_with_table(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    table: &CostTable,
    opts: &SimOptions,
) -> SimReport {
    assert!(
        opts.batching.is_none(),
        "SimOptions::batching requires simulate_batched_with_tables (or simulate)"
    );
    if faults_live(opts) {
        return simulate_faulted(queries, systems, policy, table, None, opts);
    }
    assert_sorted(queries);
    assert_eq!(table.n_queries(), queries.len(), "cost table rows must match the trace");
    assert_eq!(table.n_systems(), systems.len(), "cost table columns must match the cluster");
    let mut cluster = ClusterState::new(systems);
    let mut outcomes = Vec::with_capacity(queries.len());
    let mut batches: Vec<BatchStats> = vec![BatchStats::default(); systems.len()];
    let mut serial_energy_j = 0.0f64;
    let mut rerouted = 0u64;
    let mut overload = opts.admission.clone().map(OverloadPolicy::new);
    let mut ledger = ShedLedger::new();

    for (qi, q) in queries.iter().enumerate() {
        // retire finished work, then view queue state at the arrival
        // instant — the policy sees live depths *and* live lengths
        cluster.advance_to(q.arrival_s);
        let depths = cluster.queue_depths_at(q.arrival_s);
        let lens = cluster.queue_lens();
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let mut sid = route_query(policy, q, qi, &view, table, systems, opts.strict, &mut rerouted);

        // reject-on-arrival: the shared overload policy sees the same
        // live depths/lengths the routing policy saw. ETA on a system
        // is its serial backlog plus this query's runtime there
        // (infeasible systems estimate to ∞, so an upgrade can never
        // land on one unless the query carries no deadline — guarded
        // below). Runs strictly after `policy.assign`, so shed queries
        // still advance policy state (RoundRobin sequences stay aligned
        // between admission-on and -off runs).
        if let Some(ov) = overload.as_mut() {
            ledger.arrive(q.tenant);
            let mut eta = |s: usize| {
                if table.feasibility(qi, s) == Feasibility::Ok {
                    depths[s] + table.runtime_s(qi, s)
                } else {
                    f64::INFINITY
                }
            };
            match ov.decide(q, q.arrival_s, sid.0, &lens, &mut eta) {
                AdmitDecision::Admit(s2) => {
                    // an upgrade onto an infeasible system (possible
                    // only for deadline-free queries when every
                    // eligible system is infeasible) falls back to the
                    // routed — feasible — system
                    if s2 != sid.0 && table.feasibility(qi, s2) == Feasibility::Ok {
                        ledger.upgrade(q.tenant);
                        sid = SystemId(s2);
                    }
                    ledger.serve(q.tenant);
                }
                AdmitDecision::Shed(reason) => {
                    ledger.shed(q.tenant, reason);
                    continue;
                }
            }
        }

        let service = table.runtime_s(qi, sid.0);
        let e_j = table.energy_j(qi, sid.0);
        let node = cluster.get_mut(sid);
        let (start, finish) = node.schedule(q.arrival_s, service);
        node.energy_j += e_j;
        serial_energy_j += e_j;
        batches[sid.0].record(1, systems[sid.0].dispatch_energy_j(), 0);
        outcomes.push(QueryOutcome {
            query_id: q.id,
            system: sid.0,
            arrival_s: q.arrival_s,
            start_s: start,
            finish_s: finish,
            service_s: service,
            energy_j: e_j,
        });
    }

    finalize_report(
        policy.name(),
        &cluster,
        outcomes,
        opts,
        rerouted,
        batches,
        serial_energy_j,
        ledger.into_stats(),
    )
}

/// Which of a system's virtual worker queues a newly routed query
/// joins ([`QueueModel::PerWorker`]): the least-loaded one, where load
/// is the node's remaining busy time at `t` plus the serial runtimes of
/// its undispatched waiters. Workers are scanned in index order with
/// strict `<` improvement, so ties break to the lowest index,
/// deterministically. Single-queue layouts skip the scan entirely —
/// which is what keeps single-node classes bit-identical to the
/// per-class engine (no extra float arithmetic on that path).
fn pick_worker_queue<'a>(
    node: &NodeState,
    queues: impl ExactSizeIterator<Item = &'a VecDeque<usize>>,
    t: f64,
    table: &CostTable,
    system: usize,
) -> usize {
    if queues.len() == 1 {
        return 0;
    }
    let mut best = 0usize;
    let mut best_load = f64::INFINITY;
    for (w, pq) in queues.enumerate() {
        let backlog: f64 = pq.iter().map(|&qi| table.runtime_s(qi, system)).sum();
        let load = (node.node_free_at[w] - t).max(0.0) + backlog;
        if load < best_load {
            best_load = load;
            best = w;
        }
    }
    best
}

/// Per-(system, worker) virtual-queue state of the batched engine,
/// owned for the whole simulation so the dispatch loop allocates
/// nothing in its own buffers in steady state (the memo key built
/// inside [`BatchTable::cost`] remains the one per-dispatch
/// allocation):
///
/// - `pending` — trace indices awaiting dispatch, in arrival order
///   (ascending, since queries are routed in trace order);
/// - `window` — the incrementally maintained sorted lookahead window
///   over the first `min(window_cap, pending.len())` waiters, active
///   only when the formation policy looks past one batch (see
///   [`SortedWindow`]; members enter as they join the lookahead range
///   and leave as they dispatch, amortizing the per-dispatch re-sort
///   the PR-3 engine paid);
/// - `sel` / `pairs` / `scratch` — the selection, member-shape, and DP
///   buffers one dispatch needs, cleared and refilled per dispatch with
///   capacity retained.
struct WorkerQueue {
    pending: VecDeque<usize>,
    window: SortedWindow,
    /// selected trace indices, ascending (u64: [`SortedWindow`] keys)
    sel: Vec<u64>,
    /// `(m, n)` of the selection, in `sel` order
    pairs: Vec<(u32, u32)>,
    scratch: FormationScratch,
}

impl WorkerQueue {
    fn new() -> Self {
        Self {
            pending: VecDeque::new(),
            window: SortedWindow::new(),
            sel: Vec::new(),
            pairs: Vec::new(),
            scratch: FormationScratch::default(),
        }
    }
}

/// The PR-5 dispatch loop, kept verbatim as the **scan reference** for
/// the event-heap engine below: every outer iteration re-derives each
/// non-empty queue's due instant and takes the earliest (ties to the
/// lowest `(system, worker)` pair). Same allocation-free buffers as the
/// production engine — the two differ *only* in how the next due queue
/// is found, which is exactly what the bit-identity properties in
/// `rust/tests/properties.rs` pin. Not part of the supported API; it
/// exists so "the heap is a pure data-structure swap" stays an
/// executable claim rather than a changelog assertion.
#[doc(hidden)]
pub fn simulate_batched_with_tables_scan(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    table: &CostTable,
    batch_table: &BatchTable,
    opts: &SimOptions,
) -> SimReport {
    let bopts = opts
        .batching
        .expect("simulate_batched_with_tables_scan requires SimOptions::batching");
    assert!(
        !faults_live(opts),
        "the scan reference predates fault injection; compare fault-free configs only"
    );
    let mut sim = BatchedSim::new(queries, systems, table, batch_table, opts, bopts);

    loop {
        let next_arrival = sim.next_arrival();

        // earliest batch due to dispatch across worker queues (ties:
        // lowest (system, worker) pair, deterministically)
        let mut due: Option<(f64, usize, usize)> = None;
        for (s, sys_queues) in sim.queues.iter().enumerate() {
            for (w, wq) in sys_queues.iter().enumerate() {
                if wq.pending.is_empty() {
                    continue;
                }
                let ready = sim.queue_ready(s, w);
                if due.map_or(true, |(t, _, _)| ready < t) {
                    due = Some((ready, s, w));
                }
            }
        }

        if let Some((ready, s, w)) = due {
            // dispatch everything due before the next arrival; an
            // arrival exactly at the deadline misses the batch
            if ready <= next_arrival {
                sim.dispatch(ready, s, w);
                continue;
            }
        }

        // no batch due before the next arrival: route it
        if sim.next >= queries.len() {
            break;
        }
        // a shed arrival (`None`) changed no queue — nothing to re-scan
        let _ = sim.route_next_arrival(policy);
    }

    sim.finish(policy)
}

/// Shared per-step machinery of the batched engines: cluster, virtual
/// worker queues, dispatch, routing, and outcome accumulation. The
/// production event-heap engine ([`simulate_batched_with_tables`]) and
/// the retained scan reference (`simulate_batched_with_tables_scan`)
/// both drive exactly this struct — they differ *only* in how the next
/// due `(system, worker)` queue is located, which makes their
/// bit-identity a structural property rather than a re-implementation
/// claim (and the property suite pins it anyway).
struct BatchedSim<'a> {
    queries: &'a [Query],
    systems: &'a [SystemSpec],
    table: &'a CostTable,
    batch_table: &'a BatchTable,
    opts: &'a SimOptions,
    bopts: BatchingOptions,
    /// lookahead width when the formation policy looks past one batch;
    /// 0 = window-less (FIFO semantics, eager dispatch instants)
    window_cap: usize,
    /// full-batch membership decided at hand-off (`window_cap > 0`)
    hand_off_gated: bool,
    cluster: ClusterState,
    /// virtual worker queues: one per node (PerWorker) or one per class
    /// (PerClass); `queues[s][w]` owns the pending deque, the sorted
    /// lookahead window, and the dispatch scratch buffers — so the
    /// steady-state dispatch loop allocates nothing in the engine's own
    /// buffers (the PR-4 loop built ~4 fresh `Vec`s per dispatch; the
    /// one remaining allocation is `BatchTable::cost`'s owned memo key)
    queues: Vec<Vec<WorkerQueue>>,
    /// (trace index, outcome): dispatches interleave across systems in
    /// `ready` order, so outcomes are re-sorted to trace order at the
    /// end to stay comparable with the serial engine's reports
    outcomes: Vec<(usize, QueryOutcome)>,
    batches: Vec<BatchStats>,
    rerouted: u64,
    /// trace cursor: the next arrival not yet routed
    next: usize,
    /// `Some(cap)` iff iteration-level admission is actually live:
    /// `mode = Continuous`, admission not frozen, and `max_batch > 1`.
    /// `None` runs the historical static path byte-for-byte — which is
    /// what makes the frozen ≡ static and `max_batch = 1` ≡ serial
    /// properties structural rather than numeric.
    live_cap: Option<usize>,
    /// `episodes[s][node]`: the in-flight continuous episode on that
    /// node, if any (empty and unused when `live_cap` is `None`)
    episodes: Vec<Vec<Option<Episode>>>,
    /// scratch: `(m, joined)` pairs for decode-span pricing
    ep_pairs: Vec<(u32, u64)>,
    /// scratch: live `(m, n)` pairs for the admission check
    ep_live_mn: Vec<(u32, u32)>,
    /// scratch: candidate `(m, n)` pairs for the admission check
    ep_cand: Vec<(u32, u32)>,
    /// scratch: admission working set (live ++ admitted)
    ep_admit: Vec<(u32, u32)>,
    /// scratch: projected per-live-member relative finishes
    ep_finish: Vec<f64>,
    /// scratch: projected absolute finishes of newly admitted members
    ep_new_finish: Vec<f64>,
    /// `Some` iff SLO-aware admission is enabled — the shared
    /// [`crate::sched::overload`] policy, applied at arrival routing
    /// (reject-on-arrival, before the query ever joins a queue)
    overload: Option<OverloadPolicy>,
    /// per-tenant arrive/serve/shed accounting (empty when disabled)
    ledger: ShedLedger,
}

impl<'a> BatchedSim<'a> {
    fn new(
        queries: &'a [Query],
        systems: &'a [SystemSpec],
        table: &'a CostTable,
        batch_table: &'a BatchTable,
        opts: &'a SimOptions,
        bopts: BatchingOptions,
    ) -> Self {
        assert!(bopts.max_batch >= 1, "max_batch must be >= 1");
        assert!(
            bopts.linger_s >= 0.0 && bopts.linger_s.is_finite(),
            "linger_s must be finite and non-negative"
        );
        assert_sorted(queries);
        assert_eq!(table.n_queries(), queries.len(), "cost table rows must match the trace");
        assert_eq!(table.n_systems(), systems.len(), "cost table columns must match the cluster");
        assert_eq!(batch_table.n_systems(), systems.len(), "batch table must match the cluster");
        assert_eq!(
            table.attribution,
            batch_table.attribution(),
            "cost and batch tables must use the same energy attribution"
        );

        // When the formation policy looks past one batch (shape-aware
        // with n_bins > 1), full-batch *membership* is decided at
        // hand-off — when the queue's node can actually take the batch —
        // exactly as the coordinator's workers call take_batch when they
        // free up. Gating on node availability is what lets a backlog
        // accumulate for the lookahead window to regroup, and it does
        // not move the batch start (which was `max(arrival, free)`
        // already). Window-less formation (FIFO, or any policy at
        // max_batch = 1) keeps the eager PR-2 dispatch instant,
        // preserving the serial engine's exact float arithmetic for the
        // max_batch = 1 bit-identity property. A non-zero `window_cap`
        // also switches on the incremental sorted window — the two
        // conditions are one and the same: only a wider-than-one-batch
        // lookahead has anything to rank.
        let window_cap = {
            let cap = bopts.formation.candidate_window(bopts.max_batch);
            if bopts.max_batch > 1 && cap > bopts.max_batch {
                cap
            } else {
                0
            }
        };

        // Iteration-level admission is live only when it can actually
        // admit someone: continuous mode, not frozen, and batches wider
        // than one. Every degenerate configuration takes the static
        // code path wholesale.
        let live_cap = match bopts.mode {
            BatchMode::Continuous { max_live } if !bopts.freeze_admission && bopts.max_batch > 1 => {
                Some(if max_live == 0 { bopts.max_batch } else { max_live })
            }
            _ => None,
        };
        let episodes = if live_cap.is_some() {
            systems.iter().map(|spec| (0..spec.count.max(1)).map(|_| None).collect()).collect()
        } else {
            Vec::new()
        };

        Self {
            queries,
            systems,
            table,
            batch_table,
            opts,
            bopts,
            window_cap,
            hand_off_gated: window_cap > 0,
            cluster: ClusterState::new(systems),
            queues: systems
                .iter()
                .map(|spec| {
                    let n = match bopts.queues {
                        QueueModel::PerWorker => spec.count.max(1),
                        QueueModel::PerClass => 1,
                    };
                    (0..n).map(|_| WorkerQueue::new()).collect()
                })
                .collect(),
            outcomes: Vec::with_capacity(queries.len()),
            batches: vec![BatchStats::default(); systems.len()],
            rerouted: 0,
            next: 0,
            live_cap,
            episodes,
            ep_pairs: Vec::new(),
            ep_live_mn: Vec::new(),
            ep_cand: Vec::new(),
            ep_admit: Vec::new(),
            ep_finish: Vec::new(),
            ep_new_finish: Vec::new(),
            overload: opts.admission.clone().map(OverloadPolicy::new),
            ledger: ShedLedger::new(),
        }
    }

    /// Arrival instant of the next unrouted query (∞ once exhausted).
    fn next_arrival(&self) -> f64 {
        self.queries.get(self.next).map_or(f64::INFINITY, |q| q.arrival_s)
    }

    /// The instant queue `(s, w)` next needs service. The queue must be
    /// non-empty. Static mode: the founding instant below. Continuous
    /// mode: the earlier of the founding instant and the next decode
    /// step boundary of an episode this queue feeds — waiters admitted
    /// at a boundary leave the queue there, so a boundary earlier than
    /// the founding instant *is* the queue's due event. Boundaries on
    /// queues with nobody pending are advanced lazily instead
    /// (`catch_up` at arrival routing, `drain_episodes` at finish), so
    /// this stays strictly queue-local — the property that lets the
    /// event-heap engine re-derive only the touched queue's event per
    /// step.
    fn queue_ready(&self, s: usize, w: usize) -> f64 {
        let founding = self.founding_ready(s, w);
        match self.earliest_boundary(s, w) {
            Some((b, _)) if b <= founding => b,
            _ => founding,
        }
    }

    /// The next decode-step boundary among episodes queue `(s, w)`
    /// feeds: its own node's under `PerWorker`, the earliest across the
    /// class under `PerClass` (ties to the lowest node, matching the
    /// scan order). `None` when admission is off or no episode is live.
    fn earliest_boundary(&self, s: usize, w: usize) -> Option<(f64, usize)> {
        self.live_cap?;
        match self.bopts.queues {
            QueueModel::PerWorker => {
                self.episodes[s][w].as_ref().map(|ep| (ep.next_boundary_s, w))
            }
            QueueModel::PerClass => {
                let mut best: Option<(f64, usize)> = None;
                for (node, slot) in self.episodes[s].iter().enumerate() {
                    if let Some(ep) = slot {
                        if best.map_or(true, |(t, _)| ep.next_boundary_s < t) {
                            best = Some((ep.next_boundary_s, node));
                        }
                    }
                }
                best
            }
        }
    }

    /// The instant queue `(s, w)`'s *founding* batch becomes due — the
    /// historical static due time. The queue must be non-empty. Every
    /// input is queue-local: its own pending members, plus its own
    /// node's availability (under `PerClass` there is exactly one queue
    /// per class, so the class-wide `earliest_free` moves only on that
    /// queue's own dispatches).
    fn founding_ready(&self, s: usize, w: usize) -> f64 {
        let wq = &self.queues[s][w];
        let front = *wq.pending.front().expect("queue_ready needs a non-empty queue");
        // the instant this queue's node could take a batch: its own
        // node under PerWorker, the class-wide earliest-free node under
        // PerClass (any node may take the batch there)
        let free = match self.bopts.queues {
            QueueModel::PerWorker => self.cluster.nodes[s].node_free_at[w],
            QueueModel::PerClass => self.cluster.nodes[s].earliest_free(),
        };
        if wq.pending.len() >= self.bopts.max_batch {
            // full: due the instant the filling member arrived
            // (membership additionally waits for a free node when the
            // formation window needs a backlog — see `BatchedSim::new`).
            // Continuous mode also gates on the node: while an episode
            // runs there, waiters join it at step boundaries — which
            // sort ahead of foundings at the same instant — so a
            // founding only ever lands on an episode-free node.
            let filling = self.queries[wq.pending[self.bopts.max_batch - 1]].arrival_s;
            if self.hand_off_gated || self.live_cap.is_some() {
                free.max(filling)
            } else {
                filling
            }
        } else {
            // partial: linger from when the node could take it
            free.max(self.queries[front].arrival_s) + self.bopts.linger_s
        }
    }

    /// Service queue `(s, w)` at its due instant `ready`: in continuous
    /// mode, when a decode-step boundary is what made the queue due,
    /// advance that episode (retire + admit); otherwise found a new
    /// batch. A boundary tied with the founding instant wins — admit
    /// into the running episode before starting a new one, which is
    /// also what keeps a sparse trace (episodes always retire fully
    /// before the next founding) byte-identical to static.
    fn dispatch(&mut self, ready: f64, s: usize, w: usize) {
        if self.live_cap.is_some() {
            if let Some((b, node)) = self.earliest_boundary(s, w) {
                if b <= self.founding_ready(s, w) {
                    debug_assert_eq!(
                        b.to_bits(),
                        ready.to_bits(),
                        "a boundary-due queue must be serviced at that boundary"
                    );
                    self.advance_boundary(s, w, node);
                    return;
                }
            }
        }
        self.found_batch(ready, s, w);
    }

    /// Found queue `(s, w)`'s due batch at instant `ready`: membership
    /// into the queue's reusable buffers, joint-KV trim, node
    /// occupation, then per-member outcome attribution (static) or
    /// episode founding (continuous).
    fn found_batch(&mut self, ready: f64, s: usize, w: usize) {
        let Self {
            queries,
            systems,
            batch_table,
            bopts,
            window_cap,
            hand_off_gated,
            cluster,
            queues,
            outcomes,
            batches,
            live_cap,
            episodes,
            ep_pairs,
            ..
        } = self;
        let (queries, systems, batch_table) = (*queries, *systems, *batch_table);
        let (bopts, window_cap, hand_off_gated) = (*bopts, *window_cap, *hand_off_gated);
        let live_cap = *live_cap;
        let wq = &mut queues[s][w];
        // batch membership, into the queue's reusable buffers: the
        // drag-minimal group from the incrementally sorted window (the
        // same grouping the coordinator's take_batch_with computes —
        // see `SortedWindow`), or the FIFO prefix when the policy never
        // looks past one batch
        // a founding batch may not exceed the live-set cap either — the
        // episode it founds *is* the initial live set
        let found_cap = live_cap.map_or(bopts.max_batch, |c| bopts.max_batch.min(c));
        if hand_off_gated {
            let front = *wq.pending.front().expect("due queue has a front waiter");
            let oldest = (queries[front].output_tokens, front as u64);
            wq.window.select_drag_minimal_with_cost(
                oldest,
                found_cap,
                bopts.dispatch_cost_steps,
                &mut wq.scratch,
                &mut wq.sel,
            );
        } else {
            wq.sel.clear();
            wq.sel.extend(wq.pending.iter().take(found_cap).map(|&qi| qi as u64));
        }
        wq.pairs.clear();
        wq.pairs.extend(wq.sel.iter().map(|&qi| {
            let q = &queries[qi as usize];
            (q.input_tokens, q.output_tokens)
        }));
        // joint-KV feasibility: trim to the longest prefix of the
        // selection that fits; the tail stays queued for the next
        // dispatch
        let take = batch_table.feasible_prefix(s, &wq.pairs);
        wq.sel.truncate(take);
        wq.pairs.truncate(take);
        if hand_off_gated {
            // pending is ascending in trace index, so positions resolve
            // by binary search; descending removal keeps earlier
            // positions stable
            for &qi in wq.sel.iter().rev() {
                let pos = wq
                    .pending
                    .binary_search(&(qi as usize))
                    .expect("selected member must be pending");
                wq.pending.remove(pos);
                wq.window.remove((queries[qi as usize].output_tokens, qi));
            }
            // slide the window forward over the next-oldest waiters
            // this dispatch exposed
            while wq.window.len() < window_cap.min(wq.pending.len()) {
                let qi = wq.pending[wq.window.len()];
                wq.window.insert((queries[qi].output_tokens, qi as u64));
            }
        } else {
            // window-less selection is always the queue prefix
            for _ in 0..take {
                wq.pending.pop_front();
            }
        }
        let cost = batch_table.cost(s, &wq.pairs);
        debug_assert!(cost.is_feasible(), "trimmed batch must be feasible");
        let e_batch = batch_table.energy_j(&cost);
        let node = cluster.get_mut(SystemId(s));
        let (start, node_idx) = match bopts.queues {
            QueueModel::PerWorker => {
                (node.schedule_batch_on(w, ready, cost.runtime_s, &cost.member_finish_s), w)
            }
            QueueModel::PerClass if live_cap.is_some() => {
                // continuous mode needs to know *which* node hosts the
                // episode, so resolve `schedule_batch`'s earliest-free
                // pick (ties to the lowest index) explicitly and book
                // through the same per-node path — identical arithmetic
                let idx = node
                    .node_free_at
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .expect("system has at least one node");
                (node.schedule_batch_on(idx, ready, cost.runtime_s, &cost.member_finish_s), idx)
            }
            QueueModel::PerClass => {
                (node.schedule_batch(ready, cost.runtime_s, &cost.member_finish_s), 0)
            }
        };
        node.energy_j += e_batch;
        batches[s].record(
            take,
            systems[s].dispatch_energy_j(),
            // continuous episodes have no stragglers by construction:
            // members retire at their own n
            if live_cap.is_some() { 0 } else { FormationPolicy::straggler_steps(&wq.pairs) },
        );
        if live_cap.is_some() {
            // continuous: the batch founds an episode; outcomes are
            // attributed when the episode retires its members. Founding
            // is gated on node availability and boundaries sort ahead
            // of foundings (see `founding_ready`), so the node's
            // previous episode — if any — has always fully retired and
            // finalized by now.
            debug_assert!(
                episodes[s][node_idx].is_none(),
                "a founding lands only on an episode-free node"
            );
            let members: Vec<(usize, u32, u32)> = wq
                .sel
                .iter()
                .zip(wq.pairs.iter())
                .map(|(&qi, &(m, n))| (qi as usize, m, n))
                .collect();
            let mut ep = Episode::found(node_idx, start, &members, Arc::clone(&cost), e_batch);
            ep.refresh_next_boundary(&batch_table.energy_model().perf, &systems[s], ep_pairs);
            episodes[s][node_idx] = Some(ep);
            return;
        }
        let batch_tokens: f64 = wq.pairs.iter().map(|&(m, n)| (m + n) as f64).sum();
        for (k, &qi) in wq.sel.iter().enumerate() {
            let qi = qi as usize;
            let q = &queries[qi];
            // attribute batch energy by token share (a singleton gets
            // exactly the full batch energy)
            let share = (wq.pairs[k].0 + wq.pairs[k].1) as f64 / batch_tokens;
            outcomes.push((
                qi,
                QueryOutcome {
                    query_id: q.id,
                    system: s,
                    arrival_s: q.arrival_s,
                    start_s: start,
                    finish_s: start + cost.member_finish_s[k],
                    service_s: cost.member_finish_s[k],
                    energy_j: e_batch * share,
                },
            ));
        }
    }

    /// Advance the episode on `(s, node)` to its next decode-step
    /// boundary: retire every member whose `n` is spent, then admit the
    /// longest feasible FIFO prefix of queue `(s, w)`'s waiters into the
    /// freed live slots (joint-KV checked against the surviving live
    /// footprint — the shared [`crate::sched::admission`] policy). An
    /// admission re-prices the episode's remaining decode through
    /// [`PerfModel::decode_span_time`](crate::perf::model::PerfModel)
    /// and re-books the node's occupation and energy by the exact
    /// delta. When the last member retires, the episode finalizes into
    /// per-member outcomes.
    fn advance_boundary(&mut self, s: usize, w: usize, node: usize) {
        let Self {
            queries,
            systems,
            batch_table,
            bopts,
            window_cap,
            hand_off_gated,
            cluster,
            queues,
            outcomes,
            batches,
            live_cap,
            episodes,
            ep_pairs,
            ep_live_mn,
            ep_cand,
            ep_admit,
            ep_finish,
            ep_new_finish,
            ..
        } = self;
        let (queries, systems, batch_table) = (*queries, *systems, *batch_table);
        let (bopts, window_cap, hand_off_gated) = (*bopts, *window_cap, *hand_off_gated);
        let live_cap = live_cap.expect("advance_boundary requires continuous mode");
        let perf = &batch_table.energy_model().perf;
        let spec = &systems[s];
        let ep = episodes[s][node].as_mut().expect("advance_boundary needs a live episode");
        let t_boundary = ep.next_boundary_s;
        let retired = ep.advance_retirement(perf, spec, ep_pairs);
        debug_assert!(retired > 0, "a boundary event must retire at least one member");

        // admit the longest feasible FIFO prefix into the freed slots
        let wq = &mut queues[s][w];
        let room = live_cap.saturating_sub(ep.live.len());
        if room > 0 && !wq.pending.is_empty() {
            ep_cand.clear();
            ep_cand.extend(wq.pending.iter().take(room).map(|&qi| {
                let q = &queries[qi];
                (q.input_tokens, q.output_tokens)
            }));
            ep_live_mn.clear();
            ep_live_mn.extend(ep.live.iter().map(|lm| (lm.m, lm.n)));
            let k = admission::admit_prefix_with(perf, spec, ep_live_mn, ep_cand, room, ep_admit);
            if k > 0 {
                // each admission event pays one dispatch overhead and
                // the newcomers' prefills, exactly as a founding would
                ep.overhead_s += spec.overhead_s;
                for _ in 0..k {
                    let qi = wq.pending.pop_front().expect("admitted member must be pending");
                    let q = &queries[qi];
                    if hand_off_gated {
                        wq.window.remove((q.output_tokens, qi as u64));
                    }
                    ep.prefill_s += perf.prefill_time(spec, q.input_tokens);
                    ep.admit(LiveMember {
                        qi,
                        m: q.input_tokens,
                        n: q.output_tokens,
                        joined: ep.step,
                        admit_s: t_boundary,
                    });
                }
                while wq.window.len() < window_cap.min(wq.pending.len()) {
                    let qi = wq.pending[wq.window.len()];
                    wq.window.insert((queries[qi].output_tokens, qi as u64));
                }
                batches[s].record(k, spec.dispatch_energy_j(), 0);
                // re-book the node: the episode's projected end and
                // energy moved; `project_decode` chains the same
                // decode-span segments later boundaries will price, so
                // absent further admissions the booking is exact
                let decode_total = ep.project_decode(perf, spec, ep_pairs, ep_finish);
                let runtime = ep.overhead_s + ep.prefill_s + decode_total;
                let energy = episode_energy(
                    spec,
                    ep.overhead_s,
                    ep.prefill_s,
                    decode_total,
                    batch_table.attribution(),
                );
                ep_new_finish.clear();
                for (lm, &f) in ep.live.iter().zip(ep_finish.iter()) {
                    if lm.joined == ep.step {
                        ep_new_finish.push(ep.start_s + f);
                    }
                }
                let node_state = cluster.get_mut(SystemId(s));
                node_state.extend_batch_on(
                    node,
                    ep.start_s + runtime,
                    runtime - ep.booked_runtime_s,
                    ep_new_finish,
                );
                node_state.energy_j += energy - ep.booked_energy_j;
                ep.booked_runtime_s = runtime;
                ep.booked_energy_j = energy;
            }
        }

        if ep.live.is_empty() {
            let ep = episodes[s][node].take().expect("episode was live above");
            emit_episode_outcomes(batch_table, s, queries, outcomes, ep);
        } else {
            ep.refresh_next_boundary(perf, spec, ep_pairs);
        }
    }

    /// Lazily advance every boundary of queue `(s, w)`'s episodes that
    /// fell at or before `t`. Called when an arrival routes into the
    /// queue: while the queue sat empty its boundaries carried no
    /// admission decision (nobody was waiting), so advancing them on
    /// demand is observationally identical to advancing them on time —
    /// and an arrival exactly at a boundary misses it, mirroring the
    /// arrival-at-deadline rule for founding batches.
    fn catch_up(&mut self, s: usize, w: usize, t: f64) {
        loop {
            match self.earliest_boundary(s, w) {
                Some((b, node)) if b <= t => {
                    debug_assert!(self.queues[s][w].pending.is_empty());
                    self.advance_boundary(s, w, node)
                }
                _ => break,
            }
        }
    }

    /// Run every remaining episode to retirement. Called once at
    /// `finish`: both engine loops exit only when every pending queue
    /// is empty, so no admission decision remains and the boundaries
    /// can be replayed without consulting the clock.
    fn drain_episodes(&mut self) {
        if self.live_cap.is_none() {
            return;
        }
        for s in 0..self.systems.len() {
            for node in 0..self.episodes[s].len() {
                while self.episodes[s][node].is_some() {
                    let w = match self.bopts.queues {
                        QueueModel::PerWorker => node,
                        QueueModel::PerClass => 0,
                    };
                    debug_assert!(
                        self.queues[s][w].pending.is_empty(),
                        "finish() drains only after every waiter was serviced"
                    );
                    self.advance_boundary(s, w, node);
                }
            }
        }
    }

    /// Route the next arrival: retire finished work, build the live
    /// queue view (pending members surface as extra length and serial
    /// depth), ask the policy, and enqueue on the assigned system's
    /// least-loaded worker queue. Returns the `(system, worker)` queue
    /// joined — the one queue whose due event changed — or `None` when
    /// the shared admission policy shed the query (the trace cursor
    /// still advances; no queue changed).
    fn route_next_arrival(&mut self, policy: &mut dyn Policy) -> Option<(usize, usize)> {
        let (queries, systems, table) = (self.queries, self.systems, self.table);
        let qi = self.next;
        let q = &queries[qi];
        self.cluster.advance_to(q.arrival_s);
        let mut depths = self.cluster.queue_depths_at(q.arrival_s);
        let mut lens = self.cluster.queue_lens();
        for (s, sys_queues) in self.queues.iter().enumerate() {
            for wq in sys_queues {
                if wq.pending.is_empty() {
                    continue;
                }
                lens[s] += wq.pending.len();
                depths[s] += wq.pending.iter().map(|&qi| table.runtime_s(qi, s)).sum::<f64>();
            }
        }
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let mut sid =
            route_query(policy, q, qi, &view, table, systems, self.opts.strict, &mut self.rerouted);

        // reject-on-arrival over the same live view the routing policy
        // saw (queued runtime plus this query's own), strictly after
        // `policy.assign` so shed queries still advance policy state
        if let Some(ov) = self.overload.as_mut() {
            self.ledger.arrive(q.tenant);
            let mut eta = |s: usize| {
                if table.feasibility(qi, s) == Feasibility::Ok {
                    depths[s] + table.runtime_s(qi, s)
                } else {
                    f64::INFINITY
                }
            };
            match ov.decide(q, q.arrival_s, sid.0, &lens, &mut eta) {
                AdmitDecision::Admit(s2) => {
                    // never upgrade onto an infeasible system (only
                    // reachable for deadline-free queries when every
                    // eligible system is infeasible)
                    if s2 != sid.0 && table.feasibility(qi, s2) == Feasibility::Ok {
                        self.ledger.upgrade(q.tenant);
                        sid = SystemId(s2);
                    }
                    self.ledger.serve(q.tenant);
                }
                AdmitDecision::Shed(reason) => {
                    self.ledger.shed(q.tenant, reason);
                    self.next = qi + 1;
                    return None;
                }
            }
        }
        let w = pick_worker_queue(
            &self.cluster.nodes[sid.0],
            self.queues[sid.0].iter().map(|wq| &wq.pending),
            q.arrival_s,
            table,
            sid.0,
        );
        // replay any step boundaries this queue's episodes passed while
        // nobody was waiting — they carried no admission decision, so
        // advancing them now is identical to advancing them on time
        // (and an arrival exactly at a boundary misses it, like the
        // arrival-at-deadline rule for founding batches)
        if self.live_cap.is_some() {
            self.catch_up(sid.0, w, q.arrival_s);
        }
        let (window_cap, hand_off_gated) = (self.window_cap, self.hand_off_gated);
        let wq = &mut self.queues[sid.0][w];
        // the new waiter enters the sorted window iff it lands within
        // the lookahead cap (deeper waiters enter as dispatches expose
        // them)
        if hand_off_gated && wq.pending.len() < window_cap {
            wq.window.insert((q.output_tokens, qi as u64));
        }
        wq.pending.push_back(qi);
        self.next = qi + 1;
        Some((sid.0, w))
    }

    /// Sort outcomes back to trace order, sum the serial-equivalent
    /// energy in that order — the same float accumulation order the
    /// serial engine uses, so `max_batch = 1` stays bit-identical even
    /// though dispatches interleave across systems in `ready` order —
    /// and assemble the report.
    fn finish(mut self, policy: &mut dyn Policy) -> SimReport {
        self.drain_episodes();
        let mut outcomes = self.outcomes;
        outcomes.sort_unstable_by_key(|&(qi, _)| qi);
        let serial_energy_j: f64 =
            outcomes.iter().map(|&(qi, ref o)| self.table.energy_j(qi, o.system)).sum();
        let outcomes = outcomes.into_iter().map(|(_, o)| o).collect();
        finalize_report(
            policy.name(),
            &self.cluster,
            outcomes,
            self.opts,
            self.rerouted,
            self.batches,
            serial_energy_j,
            self.ledger.into_stats(),
        )
    }
}

/// Finalize a fully retired episode into per-member outcomes.
///
/// An episode nobody joined replays the static attribution verbatim
/// from its founding [`crate::perf::model::BatchCost`] — byte-identical
/// outcomes, which is what pins sparse continuous traces (episodes that
/// always retire before the next founding) to the static engine
/// bitwise. An episode with admissions attributes its booked
/// merged-phase energy by token share over everyone it served; each
/// member's clock runs from its own admission instant to its own
/// retirement boundary.
fn emit_episode_outcomes(
    batch_table: &BatchTable,
    s: usize,
    queries: &[Query],
    outcomes: &mut Vec<(usize, QueryOutcome)>,
    ep: Episode,
) {
    debug_assert!(ep.live.is_empty(), "finalize only fully retired episodes");
    if !ep.admitted_any {
        let cost = &ep.founding_cost;
        let e_batch = batch_table.energy_j(cost);
        let batch_tokens: f64 = ep.founding.iter().map(|&(_, m, n)| (m + n) as f64).sum();
        for (k, &(qi, m, n)) in ep.founding.iter().enumerate() {
            let q = &queries[qi];
            let share = (m + n) as f64 / batch_tokens;
            outcomes.push((
                qi,
                QueryOutcome {
                    query_id: q.id,
                    system: s,
                    arrival_s: q.arrival_s,
                    start_s: ep.start_s,
                    finish_s: ep.start_s + cost.member_finish_s[k],
                    service_s: cost.member_finish_s[k],
                    energy_j: e_batch * share,
                },
            ));
        }
        return;
    }
    let total = ep.booked_energy_j;
    let tokens = ep.total_tokens();
    for d in &ep.done {
        let q = &queries[d.qi];
        let share = (d.m + d.n) as f64 / tokens;
        let finish = ep.start_s + d.finish_rel;
        outcomes.push((
            d.qi,
            QueryOutcome {
                query_id: q.id,
                system: s,
                arrival_s: q.arrival_s,
                start_s: d.admit_s,
                finish_s: finish,
                service_s: finish - d.admit_s,
                energy_j: total * share,
            },
        ));
    }
}

/// One "queue `(s, w)`'s batch becomes due at `ready`" entry in the
/// event heap. Ordering reproduces the scan loop's strict-`<` winner
/// exactly: earliest `ready` first, ties to the lowest
/// `(system, worker)` pair — the order the scan encounters queues in.
/// `stamp` pairs the event with the queue revision it was derived from;
/// a mismatch against the live stamp marks it stale. Crate-visible so
/// the streaming engine (`sim::stream`) shares the exact ordering —
/// one tie-break definition, not two.
#[derive(Clone, Copy, Debug)]
pub(crate) struct DueEvent {
    pub(crate) ready: f64,
    pub(crate) s: u32,
    pub(crate) w: u32,
    pub(crate) stamp: u64,
}

impl PartialEq for DueEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for DueEvent {}

impl PartialOrd for DueEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for DueEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // `x + 0.0` maps -0.0 to +0.0 and is the identity on every
        // other value (due times are finite, never NaN), so `total_cmp`
        // agrees with the scan's IEEE `<` on every pair of due instants
        // — without it a -0.0 due time would outrank a +0.0 one that
        // the scan treats as tied (and resolves by queue order)
        (self.ready + 0.0)
            .total_cmp(&(other.ready + 0.0))
            .then(self.s.cmp(&other.s))
            .then(self.w.cmp(&other.w))
            .then(self.stamp.cmp(&other.stamp))
    }
}

/// Re-derive queue `(s, w)`'s due event after its inputs changed (a
/// dispatch from it, or an arrival into it): bump the queue's stamp —
/// lazily invalidating whatever event the heap still holds for it — and
/// push a fresh event if the queue still has waiters. Due times are
/// strictly queue-local (see [`BatchedSim::queue_ready`]), so the one
/// touched queue is the only one whose event can have changed.
fn refresh_due_event(
    sim: &BatchedSim,
    stamps: &mut [Vec<u64>],
    heap: &mut BinaryHeap<Reverse<DueEvent>>,
    s: usize,
    w: usize,
) {
    let stamp = &mut stamps[s][w];
    *stamp += 1;
    if sim.queues[s][w].pending.is_empty() {
        return;
    }
    heap.push(Reverse(DueEvent {
        ready: sim.queue_ready(s, w),
        s: s as u32,
        w: w as u32,
        stamp: *stamp,
    }));
}

/// Batched online simulation over prebuilt tables. Mirrors
/// `SystemQueue::take_batch` in virtual time, per **virtual worker
/// queue** — by default one queue per node ([`QueueModel::PerWorker`],
/// each node batching at its own cadence), optionally one per system
/// class ([`QueueModel::PerClass`], the coordinator's shared-queue
/// membership semantics — see [`QueueModel`]):
///
/// - a routed query joins a queue of its assigned system — the
///   least-loaded worker queue under `PerWorker` (node's remaining busy
///   time plus queued serial seconds, ties to the lowest index), the
///   single class queue under `PerClass`;
/// - a queue's batch becomes *due* the instant `max_batch` members are
///   waiting (at the filling member's arrival), or — when arrivals are
///   too sparse to fill it — `linger_s` after the first member could
///   have started on the queue's node; when the formation policy looks
///   past one batch (shape-aware, `n_bins > 1`), a full batch *forms*
///   at hand-off, once the node is free to take it — that lets a
///   backlog accumulate for regrouping, as real workers see, without
///   moving the batch start (already `max(arrival, free)`);
///   window-less formation keeps the eager dispatch instant;
/// - **which** waiters form the batch is decided by
///   [`BatchingOptions::formation`] — the FIFO prefix, or shape-aware
///   grouping of near-equal output lengths over a lookahead window
///   (the same [`crate::sched::formation`] implementation the
///   coordinator's `take_batch_with` uses); under `PerWorker` the
///   window sees only that worker's queue, so formation interacts with
///   the backlog one node actually owns;
/// - a completed batch occupies the queue's own node under `PerWorker`
///   (the class-wide earliest-free node under `PerClass`): one dispatch
///   overhead for the whole batch, per-member finish instants from
///   [`crate::perf::model::BatchCost`];
/// - batches whose joint KV footprint would OOM are trimmed to the
///   longest feasible prefix, the tail stays queued.
///
/// An arrival landing exactly at a linger deadline misses the batch,
/// matching the wall-clock batcher. Ready batches always dispatch
/// before later arrivals are routed, so the policy's queue view is
/// causal; pending (undispatched) members are surfaced to the view as
/// extra `queue_len` entries and their serial runtime as extra depth.
/// On clusters where every class has `count = 1` the two queue layouts
/// are bit-identical (property-tested in `rust/tests/properties.rs`):
/// one queue per class *is* one queue per node there, and the
/// single-queue paths do no extra arithmetic.
///
/// **Event-driven dispatch** (this PR's tentpole): instead of
/// re-scanning every virtual queue per step for the earliest due batch
/// — O(Σ `count`) work per dispatch, which dominates million-query
/// runs on wide fleets — the engine keeps a min-heap of per-queue
/// `DueEvent`s with lazy invalidation: each queue carries a revision
/// stamp, bumped whenever that queue's pending set or node availability
/// changes, and events whose stamp no longer matches are discarded on
/// pop. Because a due time depends only on queue-local state (see
/// `BatchedSim::queue_ready`), exactly one event is re-derived per
/// dispatch or arrival, so a step costs O(log #queues). The retained
/// scan loop (`simulate_batched_with_tables_scan`) pins this engine
/// bit-identical — same winners, same tie-breaks, same floats — across
/// seeds, policies, queue models, and formation policies
/// (`rust/tests/properties.rs`).
pub fn simulate_batched_with_tables(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    table: &CostTable,
    batch_table: &BatchTable,
    opts: &SimOptions,
) -> SimReport {
    let bopts = opts
        .batching
        .expect("simulate_batched_with_tables requires SimOptions::batching");
    if faults_live(opts) {
        return simulate_faulted(queries, systems, policy, table, Some(batch_table), opts);
    }
    let mut sim = BatchedSim::new(queries, systems, table, batch_table, opts, bopts);
    // one live revision stamp per queue; an event is current iff its
    // stamp matches
    let mut stamps: Vec<Vec<u64>> = sim.queues.iter().map(|sq| vec![0u64; sq.len()]).collect();
    let mut heap: BinaryHeap<Reverse<DueEvent>> = BinaryHeap::new();

    loop {
        let next_arrival = sim.next_arrival();

        // earliest live due event, discarding stale ones lazily; the
        // heap order matches the scan's (ready, system, worker) winner
        let mut due: Option<(f64, usize, usize)> = None;
        while let Some(&Reverse(ev)) = heap.peek() {
            let (s, w) = (ev.s as usize, ev.w as usize);
            if ev.stamp != stamps[s][w] {
                heap.pop();
                continue;
            }
            due = Some((ev.ready, s, w));
            break;
        }

        if let Some((ready, s, w)) = due {
            // dispatch everything due before the next arrival; an
            // arrival exactly at the deadline misses the batch
            if ready <= next_arrival {
                heap.pop(); // consume the event just peeked
                sim.dispatch(ready, s, w);
                // the dispatch changed this queue's pending set and its
                // node's availability — and, by queue-locality, nothing
                // any other queue's due time depends on
                refresh_due_event(&sim, &mut stamps, &mut heap, s, w);
                continue;
            }
        }

        // no batch due before the next arrival: route it
        if sim.next >= queries.len() {
            break;
        }
        // a shed arrival returns `None`: no queue changed, no event to
        // refresh — the trace cursor advanced and the loop continues
        if let Some((s, w)) = sim.route_next_arrival(policy) {
            refresh_due_event(&sim, &mut stamps, &mut heap, s, w);
        }
    }

    sim.finish(policy)
}

/// The PR-4 dispatch loop, kept verbatim as the **reference
/// implementation** for the allocation-free engine above: membership
/// through [`FormationPolicy::select`] with fresh candidate/shape/
/// selection/member vectors every dispatch. The property suite
/// (`prop_batched_engine_matches_reference` in
/// `rust/tests/properties.rs`) pins the production engine bit-identical
/// to this one — batch compositions, outcomes, straggler accounting,
/// every float — across seeds, queue models, and formation policies.
/// Not part of the supported API; it exists so "bit-identical to the
/// previous implementation" stays an executable claim rather than a
/// changelog assertion.
#[doc(hidden)]
pub fn simulate_batched_with_tables_reference(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    table: &CostTable,
    batch_table: &BatchTable,
    opts: &SimOptions,
) -> SimReport {
    let bopts = opts
        .batching
        .expect("simulate_batched_with_tables_reference requires SimOptions::batching");
    assert!(bopts.max_batch >= 1, "max_batch must be >= 1");
    assert!(
        bopts.linger_s >= 0.0 && bopts.linger_s.is_finite(),
        "linger_s must be finite and non-negative"
    );
    assert_sorted(queries);
    assert_eq!(table.n_queries(), queries.len(), "cost table rows must match the trace");
    assert_eq!(table.n_systems(), systems.len(), "cost table columns must match the cluster");
    assert_eq!(batch_table.n_systems(), systems.len(), "batch table must match the cluster");
    assert_eq!(
        table.attribution,
        batch_table.attribution(),
        "cost and batch tables must use the same energy attribution"
    );
    assert!(
        bopts.mode == BatchMode::Static && bopts.dispatch_cost_steps == 0,
        "the reference engine implements only static, zero-dispatch-cost batching"
    );
    assert!(
        opts.admission.is_none(),
        "the reference engine predates admission; compare admission-free configs only"
    );
    assert!(
        !faults_live(opts),
        "the reference engine predates fault injection; compare fault-free configs only"
    );

    let mut cluster = ClusterState::new(systems);
    let mut pending: Vec<Vec<VecDeque<usize>>> = systems
        .iter()
        .map(|spec| {
            let queues = match bopts.queues {
                QueueModel::PerWorker => spec.count.max(1),
                QueueModel::PerClass => 1,
            };
            (0..queues).map(|_| VecDeque::new()).collect()
        })
        .collect();
    let mut outcomes: Vec<(usize, QueryOutcome)> = Vec::with_capacity(queries.len());
    let mut batches: Vec<BatchStats> = vec![BatchStats::default(); systems.len()];
    let mut rerouted = 0u64;
    let mut next = 0usize;

    let hand_off_gated = bopts.max_batch > 1
        && bopts.formation.candidate_window(bopts.max_batch) > bopts.max_batch;

    loop {
        let next_arrival = queries.get(next).map_or(f64::INFINITY, |q| q.arrival_s);

        let mut due: Option<(f64, usize, usize)> = None;
        for (s, queues) in pending.iter().enumerate() {
            for (w, pq) in queues.iter().enumerate() {
                let Some(&front) = pq.front() else { continue };
                let free = match bopts.queues {
                    QueueModel::PerWorker => cluster.nodes[s].node_free_at[w],
                    QueueModel::PerClass => cluster.nodes[s].earliest_free(),
                };
                let ready = if pq.len() >= bopts.max_batch {
                    let filling = queries[pq[bopts.max_batch - 1]].arrival_s;
                    if hand_off_gated {
                        free.max(filling)
                    } else {
                        filling
                    }
                } else {
                    free.max(queries[front].arrival_s) + bopts.linger_s
                };
                if due.map_or(true, |(t, _, _)| ready < t) {
                    due = Some((ready, s, w));
                }
            }
        }

        if let Some((ready, s, w)) = due {
            if ready <= next_arrival {
                let window =
                    bopts.formation.candidate_window(bopts.max_batch).min(pending[s][w].len());
                let cand: Vec<usize> = pending[s][w].iter().take(window).copied().collect();
                let shapes: Vec<(u32, u32)> = cand
                    .iter()
                    .map(|&qi| (queries[qi].input_tokens, queries[qi].output_tokens))
                    .collect();
                let sel = bopts.formation.select(&shapes, bopts.max_batch);
                let pairs: Vec<(u32, u32)> = sel.iter().map(|&i| shapes[i]).collect();
                let take = batch_table.feasible_prefix(s, &pairs);
                let members: Vec<usize> = sel[..take].iter().map(|&i| cand[i]).collect();
                for &i in sel[..take].iter().rev() {
                    pending[s][w].remove(i);
                }
                let pairs = &pairs[..take];
                let cost = batch_table.cost(s, pairs);
                debug_assert!(cost.is_feasible(), "trimmed batch must be feasible");
                let e_batch = batch_table.energy_j(&cost);
                let node = cluster.get_mut(SystemId(s));
                let start = match bopts.queues {
                    QueueModel::PerWorker => {
                        node.schedule_batch_on(w, ready, cost.runtime_s, &cost.member_finish_s)
                    }
                    QueueModel::PerClass => {
                        node.schedule_batch(ready, cost.runtime_s, &cost.member_finish_s)
                    }
                };
                node.energy_j += e_batch;
                batches[s].record(
                    take,
                    systems[s].dispatch_energy_j(),
                    FormationPolicy::straggler_steps(pairs),
                );
                let batch_tokens: f64 =
                    pairs.iter().map(|&(m, n)| (m + n) as f64).sum();
                for (k, &qi) in members.iter().enumerate() {
                    let q = &queries[qi];
                    let share = (pairs[k].0 + pairs[k].1) as f64 / batch_tokens;
                    outcomes.push((
                        qi,
                        QueryOutcome {
                            query_id: q.id,
                            system: s,
                            arrival_s: q.arrival_s,
                            start_s: start,
                            finish_s: start + cost.member_finish_s[k],
                            service_s: cost.member_finish_s[k],
                            energy_j: e_batch * share,
                        },
                    ));
                }
                continue;
            }
        }

        let Some(q) = queries.get(next) else { break };
        cluster.advance_to(q.arrival_s);
        let mut depths = cluster.queue_depths_at(q.arrival_s);
        let mut lens = cluster.queue_lens();
        for (s, queues) in pending.iter().enumerate() {
            for pq in queues {
                if pq.is_empty() {
                    continue;
                }
                lens[s] += pq.len();
                depths[s] += pq.iter().map(|&qi| table.runtime_s(qi, s)).sum::<f64>();
            }
        }
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let sid = route_query(policy, q, next, &view, table, systems, opts.strict, &mut rerouted);
        let w = pick_worker_queue(
            &cluster.nodes[sid.0],
            pending[sid.0].iter(),
            q.arrival_s,
            table,
            sid.0,
        );
        pending[sid.0][w].push_back(next);
        next += 1;
    }

    outcomes.sort_unstable_by_key(|&(qi, _)| qi);
    let serial_energy_j: f64 =
        outcomes.iter().map(|&(qi, ref o)| table.energy_j(qi, o.system)).sum();
    let outcomes = outcomes.into_iter().map(|(_, o)| o).collect();
    finalize_report(
        policy.name(),
        &cluster,
        outcomes,
        opts,
        rerouted,
        batches,
        serial_energy_j,
        Vec::new(),
    )
}

/// One unit of dispatchable work in the fault-aware engine: a trace
/// query or a retry of one. `orig` keys the query's trace row (cost
/// pricing, outcome ordering, retry attribution) while `enq_s` is when
/// it entered its current queue — the original arrival for first
/// attempts, the backoff expiry for retries. `arrival_s` stays the
/// *original* arrival throughout, so the final outcome's latency spans
/// every failed attempt and backoff.
#[derive(Clone, Copy, Debug)]
struct FaultJob {
    orig: u64,
    id: u64,
    arrival_s: f64,
    enq_s: f64,
    m: u32,
    n: u32,
    tenant: u32,
}

/// The fault-aware simulation loop — one engine for every materialized
/// configuration once [`SimOptions::faults`] actually injects something
/// (both [`simulate_with_table`] and [`simulate_batched_with_tables`]
/// divert here; fault-free runs never reach this code, which is what
/// keeps them bit-identical to the historical engines).
///
/// The model deliberately trades the incremental machinery of the
/// fault-free engines for an auditable event loop:
///
/// - one FIFO queue per system class; batches are FIFO prefixes,
///   joint-KV trimmed through the same [`BatchTable`] (batched configs)
///   or priced per query through the same [`CostTable`] (serial), so
///   retried work is re-priced through the very tables the fault-free
///   run used;
/// - dispatch lands on the node with the earliest *fault-adjusted*
///   availability — a down node is skipped while a sibling is up, which
///   is the degraded-fleet rescheduling the coordinator mirrors;
/// - a crash mid-span books the partial runtime and energy on the node
///   (surfaced as [`SimReport::wasted_energy_j`]), requeues every
///   member through [`crate::sched::faults::RetryPolicy`]'s capped
///   exponential backoff (retries may move to the minimum-ETA feasible
///   system), and abandons members that exhausted their attempts —
///   `arrived == served + shed + abandoned` stays u64-exact per tenant;
/// - slowdown windows stretch a span's runtime and energy by
///   `slow_factor`, sampled at span start.
///
/// Approximations, documented here and in ARCHITECTURE.md: batching is
/// static FIFO-prefix under faults (formation lookahead, per-worker
/// queue cadence, and iteration-level admission are fault-free-only
/// refinements), and down nodes still burn their idle floor while
/// under repair when idle energy is enabled.
fn simulate_faulted(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    table: &CostTable,
    batch_table: Option<&BatchTable>,
    opts: &SimOptions,
) -> SimReport {
    let fcfg = opts.faults.as_ref().expect("simulate_faulted requires SimOptions::faults");
    debug_assert!(fcfg.enabled(), "disabled fault configs take the fault-free engines");
    if let Err(e) = fcfg.validate() {
        panic!("invalid fault config: {e}");
    }
    assert_sorted(queries);
    assert_eq!(table.n_queries(), queries.len(), "cost table rows must match the trace");
    assert_eq!(table.n_systems(), systems.len(), "cost table columns must match the cluster");
    let (max_batch, linger_s) = match (&opts.batching, batch_table) {
        (Some(b), Some(bt)) => {
            assert!(b.max_batch >= 1, "max_batch must be >= 1");
            assert!(
                b.linger_s >= 0.0 && b.linger_s.is_finite(),
                "linger_s must be finite and non-negative"
            );
            assert_eq!(bt.n_systems(), systems.len(), "batch table must match the cluster");
            (b.max_batch, b.linger_s)
        }
        (None, None) => (1, 0.0),
        _ => panic!("batching options and batch table must be supplied together"),
    };

    let mut fs = FaultState::new(fcfg, systems.len());
    let mut cluster = ClusterState::new(systems);
    let mut queues: Vec<VecDeque<FaultJob>> = (0..systems.len()).map(|_| VecDeque::new()).collect();
    let mut outcomes: Vec<(u64, QueryOutcome)> = Vec::with_capacity(queries.len());
    let mut batches: Vec<BatchStats> = vec![BatchStats::default(); systems.len()];
    let mut rerouted = 0u64;
    let mut overload = opts.admission.clone().map(OverloadPolicy::new);
    // fault mode always runs the ledger, admission or not: abandonment
    // makes conservation non-vacuous even for admit-everything configs
    let mut ledger = ShedLedger::new();
    let mut next = 0usize;
    let mut popped: Vec<FaultJob> = Vec::new();
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    let mut member_rel: Vec<f64> = Vec::new();

    loop {
        let next_arrival = queries.get(next).map_or(f64::INFINITY, |q| q.arrival_s);
        let next_retry = fs.next_due().unwrap_or(f64::INFINITY);
        let next_in = next_arrival.min(next_retry);

        // earliest due batch across the class queues (strict `<`, so
        // ties break to the lowest system index)
        let mut due: Option<(f64, usize)> = None;
        for (s, q) in queues.iter().enumerate() {
            let Some(front) = q.front() else { continue };
            let free = cluster.nodes[s].earliest_free();
            let ready = if q.len() >= max_batch {
                free.max(q[max_batch - 1].enq_s)
            } else {
                free.max(front.enq_s) + linger_s
            };
            if due.map_or(true, |(t, _)| ready < t) {
                due = Some((ready, s));
            }
        }

        if let Some((ready, s)) = due {
            // dispatch everything due before the next input event (an
            // arrival or a retry exactly at the deadline misses it)
            if ready <= next_in {
                // FIFO-prefix membership, joint-KV trimmed; the tail
                // returns to the head of the queue in order
                popped.clear();
                let cap = max_batch.min(queues[s].len());
                popped.extend(queues[s].drain(..cap));
                pairs.clear();
                pairs.extend(popped.iter().map(|j| (j.m, j.n)));
                let take = match batch_table {
                    Some(bt) => bt.feasible_prefix(s, &pairs),
                    None => 1,
                };
                assert!(take >= 1, "batch head must be individually feasible on its system");
                for j in popped.drain(take..).rev() {
                    queues[s].push_front(j);
                }
                pairs.truncate(take);

                member_rel.clear();
                let (base_dur, e_base) = match batch_table {
                    Some(bt) => {
                        let cost = bt.cost(s, &pairs);
                        debug_assert!(cost.is_feasible(), "trimmed batch must be feasible");
                        member_rel.extend_from_slice(&cost.member_finish_s);
                        (cost.runtime_s, bt.energy_j(&cost))
                    }
                    None => {
                        let row = popped[0].orig as usize;
                        let dur = table.runtime_s(row, s);
                        member_rel.push(dur);
                        (dur, table.energy_j(row, s))
                    }
                };

                // degraded-fleet node pick: earliest *fault-adjusted*
                // start over the class's nodes (strict `<`, ties to the
                // lowest index) — a down node is skipped while a
                // sibling is up
                let mut node_idx = 0usize;
                let mut best_start = f64::INFINITY;
                for (w, &free_w) in cluster.nodes[s].node_free_at.iter().enumerate() {
                    let est = fs.plan.up_at(s, w, ready.max(free_w));
                    if est < best_start {
                        best_start = est;
                        node_idx = w;
                    }
                }
                let free_n = cluster.nodes[s].node_free_at[node_idx];
                let att = fs.plan.attempt_span(s, node_idx, ready.max(free_n), base_dur);
                debug_assert_eq!(att.start_s.to_bits(), best_start.to_bits());
                let e_scaled = e_base * att.factor;

                if let Some(c) = att.crash_s {
                    // the node really ran [start, crash) and burned the
                    // partial energy; nobody gets an outcome
                    let e_partial = e_scaled * att.executed_fraction();
                    fs.wasted_energy_j += e_partial;
                    let resume = fs.plan.up_at(s, node_idx, c);
                    cluster.nodes[s].book_crash_on(node_idx, att.start_s, c, resume, e_partial);
                    for j in &popped {
                        let a = RetryAttempt {
                            due_s: 0.0,
                            orig: j.orig,
                            system: s,
                            id: j.id,
                            arrival_s: j.arrival_s,
                            m: j.m,
                            n: j.n,
                            row: j.orig as usize,
                            tenant: j.tenant,
                        };
                        if fs.fail(a, c).is_none() {
                            ledger.abandon(j.tenant);
                        }
                    }
                } else {
                    for f in member_rel.iter_mut() {
                        *f *= att.factor;
                    }
                    let start =
                        cluster.nodes[s].schedule_batch_on(node_idx, att.start_s, att.dur_s, &member_rel);
                    debug_assert_eq!(start.to_bits(), att.start_s.to_bits());
                    cluster.nodes[s].energy_j += e_scaled;
                    batches[s].record(
                        take,
                        systems[s].dispatch_energy_j(),
                        FormationPolicy::straggler_steps(&pairs),
                    );
                    let batch_tokens: f64 = pairs.iter().map(|&(m, n)| (m + n) as f64).sum();
                    for (k, j) in popped.iter().enumerate() {
                        let share = (pairs[k].0 + pairs[k].1) as f64 / batch_tokens;
                        outcomes.push((
                            j.orig,
                            QueryOutcome {
                                query_id: j.id,
                                system: s,
                                arrival_s: j.arrival_s,
                                start_s: start,
                                finish_s: start + member_rel[k],
                                service_s: member_rel[k],
                                energy_j: e_scaled * share,
                            },
                        ));
                        ledger.serve(j.tenant);
                        fs.served(j.orig);
                    }
                }
                continue;
            }
        }

        if next_in == f64::INFINITY {
            break;
        }

        if next_arrival <= next_retry {
            // route the next trace arrival (arrivals win ties, so the
            // trace keeps its deterministic precedence over backoffs)
            let qi = next;
            let q = &queries[qi];
            next += 1;
            cluster.advance_to(q.arrival_s);
            let mut depths = cluster.queue_depths_at(q.arrival_s);
            let mut lens = cluster.queue_lens();
            for (s, pq) in queues.iter().enumerate() {
                if pq.is_empty() {
                    continue;
                }
                lens[s] += pq.len();
                depths[s] += pq.iter().map(|j| table.runtime_s(j.orig as usize, s)).sum::<f64>();
            }
            let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
            let mut sid =
                route_query(policy, q, qi, &view, table, systems, opts.strict, &mut rerouted);
            ledger.arrive(q.tenant);
            if let Some(ov) = overload.as_mut() {
                let mut eta = |s: usize| {
                    if table.feasibility(qi, s) == Feasibility::Ok {
                        depths[s] + table.runtime_s(qi, s)
                    } else {
                        f64::INFINITY
                    }
                };
                match ov.decide(q, q.arrival_s, sid.0, &lens, &mut eta) {
                    AdmitDecision::Admit(s2) => {
                        if s2 != sid.0 && table.feasibility(qi, s2) == Feasibility::Ok {
                            ledger.upgrade(q.tenant);
                            sid = SystemId(s2);
                        }
                    }
                    AdmitDecision::Shed(reason) => {
                        ledger.shed(q.tenant, reason);
                        continue;
                    }
                }
            }
            queues[sid.0].push_back(FaultJob {
                orig: qi as u64,
                id: q.id,
                arrival_s: q.arrival_s,
                enq_s: q.arrival_s,
                m: q.input_tokens,
                n: q.output_tokens,
                tenant: q.tenant,
            });
        } else {
            // a retry's backoff expired: requeue it, on the failed
            // system or — when the policy allows — on the system with
            // the minimum estimated completion time (backlog + its own
            // runtime; strict `<`, ties to the lowest index, the
            // upgrade shape `OverloadPolicy` uses). Already admitted:
            // retries bypass admission and the routing policy.
            let a = fs.pop_due().expect("next_retry was finite");
            cluster.advance_to(a.due_s);
            let target = if fs.retry.retry_other_system {
                let depths = cluster.queue_depths_at(a.due_s);
                let mut best = a.system;
                let mut best_eta = f64::INFINITY;
                for (s, d) in depths.iter().enumerate() {
                    if table.feasibility(a.row, s) != Feasibility::Ok {
                        continue;
                    }
                    let backlog: f64 =
                        queues[s].iter().map(|j| table.runtime_s(j.orig as usize, s)).sum();
                    let eta = d + backlog + table.runtime_s(a.row, s);
                    if eta < best_eta {
                        best_eta = eta;
                        best = s;
                    }
                }
                best
            } else {
                a.system
            };
            queues[target].push_back(FaultJob {
                orig: a.orig,
                id: a.id,
                arrival_s: a.arrival_s,
                enq_s: a.due_s,
                m: a.m,
                n: a.n,
                tenant: a.tenant,
            });
        }
    }

    debug_assert_eq!(fs.abandoned, ledger.total_abandoned(), "abandonment double-entry");
    outcomes.sort_unstable_by_key(|&(orig, _)| orig);
    let serial_energy_j: f64 =
        outcomes.iter().map(|&(orig, ref o)| table.energy_j(orig as usize, o.system)).sum();
    let outcomes = outcomes.into_iter().map(|(_, o)| o).collect();
    let mut report = finalize_report(
        policy.name(),
        &cluster,
        outcomes,
        opts,
        rerouted,
        batches,
        serial_energy_j,
        ledger.into_stats(),
    );
    report.retries = fs.retries_by_system;
    report.wasted_energy_j = fs.wasted_energy_j;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::PolicyConfig;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::model::PerfModel;
    use crate::sched::policy::build_policy;
    use crate::workload::alpaca::AlpacaModel;
    use crate::workload::generator::{Arrival, TraceGenerator};

    fn energy() -> EnergyModel {
        EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()))
    }

    fn run(policy_cfg: PolicyConfig, queries: &[Query]) -> SimReport {
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&policy_cfg, em.clone(), &systems);
        simulate(queries, &systems, p.as_mut(), &em, &SimOptions::default())
    }

    #[test]
    fn every_query_processed_exactly_once() {
        // Eq. 3–4: partition property
        let queries = AlpacaModel::default().trace(3, 5000);
        let r = run(PolicyConfig::RoundRobin, &queries);
        assert_eq!(r.outcomes.len(), queries.len());
        let mut ids: Vec<u64> = r.outcomes.iter().map(|o| o.query_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), queries.len());
        assert_eq!(r.routing_counts().iter().sum::<u64>(), queries.len() as u64);
    }

    #[test]
    fn energy_conservation() {
        let queries = AlpacaModel::default().trace(4, 3000);
        for cfg in [
            PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
            PolicyConfig::Cost { lambda: 1.0 },
            PolicyConfig::AllOn("Swing-A100".into()),
        ] {
            let r = run(cfg, &queries);
            assert!(r.energy_conserved(), "{}", r.policy);
        }
    }

    #[test]
    fn hybrid_threshold_saves_energy_vs_all_a100() {
        // the paper's headline mechanism, end-to-end through the sim
        let queries = AlpacaModel::default().trace(2024, 20_000);
        let hybrid = run(
            PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
            &queries,
        );
        let baseline = run(PolicyConfig::AllOn("Swing-A100".into()), &queries);
        let saving = 1.0 - hybrid.total_energy_j / baseline.total_energy_j;
        assert!(
            (0.005..=0.20).contains(&saving),
            "hybrid saving {:.1}% outside plausible band",
            saving * 100.0
        );
        // but costs runtime (paper §6.3's stated trade-off)
        assert!(hybrid.total_service_s > baseline.total_service_s);
    }

    #[test]
    fn infeasible_fallback_rescues_queries() {
        // all-on-M1 with big generations → fallback must reroute
        let queries = vec![Query::new(0, 8, 4096), Query::new(1, 8, 8)];
        let r = run(PolicyConfig::AllOn("M1-Pro".into()), &queries);
        assert_eq!(r.outcomes.len(), 2);
        // the 4096-generation query cannot have run on the M1
        let big = r.outcomes.iter().find(|o| o.query_id == 0).unwrap();
        assert_ne!(big.system, 0);
        let small = r.outcomes.iter().find(|o| o.query_id == 1).unwrap();
        assert_eq!(small.system, 0);
        // the fallback is visible in the report
        assert_eq!(r.rerouted, 1);
    }

    #[test]
    #[should_panic(expected = "routed infeasible")]
    fn strict_mode_panics_on_infeasible() {
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::AllOn("M1-Pro".into()), em.clone(), &systems);
        let queries = vec![Query::new(0, 8, 4096)];
        simulate(&queries, &systems, p.as_mut(), &em, &SimOptions { strict: true, ..Default::default() });
    }

    #[test]
    fn online_arrivals_queue_properly() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 50.0 }, 5).generate(500);
        let r = run(PolicyConfig::JoinShortestQueue, &queries);
        // starts never precede arrivals; finishes never precede starts
        for o in &r.outcomes {
            assert!(o.start_s >= o.arrival_s - 1e-9);
            assert!(o.finish_s >= o.start_s);
        }
        // under load, someone must have waited
        assert!(r.outcomes.iter().any(|o| o.queue_wait_s() > 0.0));
        // a feasible-everywhere workload never triggers the fallback
        assert_eq!(r.rerouted, 0);
    }

    /// Tentpole smoke: overload with a queue budget sheds in both the
    /// serial and batched engines, and the per-tenant ledger conserves
    /// arrivals exactly (`arrived == outcomes + shed`, u64).
    #[test]
    fn admission_conserves_and_sheds_under_overload() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 500.0 }, 7).generate(2000);
        let systems = system_catalog();
        let em = energy();
        let adm = AdmissionConfig { queue_budget: 8, ..AdmissionConfig::default() };
        let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let r = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions { admission: Some(adm.clone()), ..Default::default() },
        );
        let arrived: u64 = r.shed.iter().map(|s| s.arrived).sum();
        assert_eq!(arrived, queries.len() as u64);
        assert_eq!(r.outcomes.len() as u64 + r.total_shed(), queries.len() as u64);
        assert!(r.total_shed() > 0, "500 q/s must overload an 8-deep budget");
        assert!(r.energy_conserved());

        let mut p2 = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let rb = simulate(
            &queries,
            &systems,
            p2.as_mut(),
            &em,
            &SimOptions {
                admission: Some(adm),
                batching: Some(BatchingOptions::new(4, 0.05)),
                ..Default::default()
            },
        );
        assert_eq!(rb.outcomes.len() as u64 + rb.total_shed(), queries.len() as u64);
        assert!(rb.total_shed() > 0);
        assert!(rb.energy_conserved());
    }

    /// A deadline no system can meet sheds everything with `SloBust`;
    /// a generous one admits everything (reports empty-shed totals).
    #[test]
    fn slo_deadlines_shed_or_admit() {
        let queries: Vec<Query> = (0..20u64).map(|id| Query::new(id, 64, 64)).collect();
        let systems = system_catalog();
        let em = energy();
        let tight = AdmissionConfig { default_slo_s: 1e-9, ..AdmissionConfig::default() };
        let mut p = build_policy(&PolicyConfig::RoundRobin, em.clone(), &systems);
        let r = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions { admission: Some(tight), ..Default::default() },
        );
        assert_eq!(r.outcomes.len(), 0);
        assert_eq!(r.shed.iter().map(|s| s.shed_slo).sum::<u64>(), 20);

        let loose = AdmissionConfig { default_slo_s: 1e9, ..AdmissionConfig::default() };
        let mut p2 = build_policy(&PolicyConfig::RoundRobin, em.clone(), &systems);
        let r2 = simulate(
            &queries,
            &systems,
            p2.as_mut(),
            &em,
            &SimOptions { admission: Some(loose), ..Default::default() },
        );
        assert_eq!(r2.outcomes.len(), 20);
        assert_eq!(r2.total_shed(), 0);
    }

    #[test]
    fn idle_energy_accounting() {
        let queries = AlpacaModel::default().trace(6, 200);
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::AllOn("Swing-A100".into()), em.clone(), &systems);
        let with_idle = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions { include_idle_energy: true, ..Default::default() },
        );
        assert!(with_idle.idle_energy_j > 0.0);
        assert!(with_idle.total_energy_j > with_idle.systems.iter().map(|s| s.energy_j).sum::<f64>());
    }

    /// A probe that routes like JSQ-by-length and records every view it
    /// was shown — the regression instrument for the stale-queue bug.
    struct LenJsqProbe {
        seen_lens: Vec<Vec<usize>>,
        seen_depths: Vec<Vec<f64>>,
    }

    impl Policy for LenJsqProbe {
        fn name(&self) -> String {
            "len-jsq-probe".into()
        }

        fn assign(&mut self, _q: &Query, view: &ClusterView) -> SystemId {
            self.seen_lens.push(view.queue_len.to_vec());
            self.seen_depths.push(view.queue_depth_s.to_vec());
            let best = view
                .queue_len
                .iter()
                .enumerate()
                .min_by_key(|&(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap_or(0);
            SystemId(best)
        }
    }

    /// Regression for the seed bug where `queue_len` was only ever
    /// incremented: a queue-length JSQ on a *drained* cluster must route
    /// exactly like a fresh cluster, because the view's lengths (and
    /// depths) must have fallen back to zero.
    #[test]
    fn drained_cluster_routes_like_fresh_cluster() {
        let systems = system_catalog();
        let em = energy();
        // a burst at t=0 followed by one arrival long after everything
        // finished (Alpaca service times are far below 1e6 s)
        let mut queries: Vec<Query> = (0..50u64).map(|id| Query::new(id, 64, 64)).collect();
        let mut late = Query::new(50, 64, 64);
        late.arrival_s = 1.0e6;
        queries.push(late);

        let mut probe = LenJsqProbe { seen_lens: Vec::new(), seen_depths: Vec::new() };
        let drained =
            simulate(&queries, &systems, &mut probe, &em, &SimOptions::default());
        // mid-burst the probe must have seen non-zero backlog...
        assert!(
            probe.seen_lens.iter().any(|lens| lens.iter().any(|&l| l > 0)),
            "burst never surfaced in queue_len — view is not live"
        );
        // ...but the drained arrival sees an all-zero view, exactly like
        // the first query of a fresh simulation
        let last_lens = probe.seen_lens.last().unwrap();
        let last_depths = probe.seen_depths.last().unwrap();
        assert!(last_lens.iter().all(|&l| l == 0), "stale queue_len: {last_lens:?}");
        assert!(last_depths.iter().all(|&d| d == 0.0), "stale depth: {last_depths:?}");
        assert_eq!(probe.seen_lens.first().unwrap(), last_lens);

        // and the routing decision matches a fresh cluster's first query
        let mut fresh_probe = LenJsqProbe { seen_lens: Vec::new(), seen_depths: Vec::new() };
        let fresh = simulate(
            &[Query::new(0, 64, 64)],
            &systems,
            &mut fresh_probe,
            &em,
            &SimOptions::default(),
        );
        assert_eq!(
            drained.outcomes.last().unwrap().system,
            fresh.outcomes[0].system,
            "drained cluster must route like a fresh cluster"
        );
    }

    /// The built-in (depth-based) JSQ agrees between drained and fresh
    /// clusters end-to-end through `build_policy`.
    #[test]
    fn jsq_on_drained_cluster_matches_fresh() {
        let systems = system_catalog();
        let em = energy();
        let mut queries: Vec<Query> = (0..30u64).map(|id| Query::new(id, 128, 32)).collect();
        let mut late = Query::new(30, 128, 32);
        late.arrival_s = 1.0e6;
        queries.push(late);
        let mut p = build_policy(&PolicyConfig::JoinShortestQueue, em.clone(), &systems);
        let drained = simulate(&queries, &systems, p.as_mut(), &em, &SimOptions::default());
        let mut p2 = build_policy(&PolicyConfig::JoinShortestQueue, em.clone(), &systems);
        let fresh = simulate(
            &[Query::new(0, 128, 32)],
            &systems,
            p2.as_mut(),
            &em,
            &SimOptions::default(),
        );
        assert_eq!(drained.outcomes.last().unwrap().system, fresh.outcomes[0].system);
    }

    /// Satellite regression: an unsorted trace must refuse to run even
    /// in release builds (the guard was a `debug_assert!` before, so
    /// release-mode sweeps could silently produce garbage queue views).
    #[test]
    #[should_panic(expected = "sorted by arrival")]
    fn unsorted_trace_panics_in_any_build() {
        let mut queries = vec![Query::new(0, 16, 16), Query::new(1, 16, 16)];
        queries[0].arrival_s = 5.0;
        queries[1].arrival_s = 1.0;
        run(PolicyConfig::RoundRobin, &queries);
    }

    #[test]
    #[should_panic(expected = "sorted by arrival")]
    fn unsorted_trace_panics_in_batched_mode_too() {
        let mut queries = vec![Query::new(0, 16, 16), Query::new(1, 16, 16)];
        queries[0].arrival_s = 5.0;
        queries[1].arrival_s = 1.0;
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::RoundRobin, em.clone(), &systems);
        simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions {
                batching: Some(BatchingOptions::new(4, 0.1)),
                ..Default::default()
            },
        );
    }

    /// Satellite regression: multi-node idle-energy accounting. Busy
    /// seconds can never exceed makespan × node count, and the idle
    /// charge must equal the exact per-class complement.
    #[test]
    fn multi_node_idle_energy_accounting() {
        let mut systems = system_catalog();
        systems[1].count = 3; // 3 × A100
        let em = energy();
        let queries = AlpacaModel::default().trace(9, 400);
        let mut p = build_policy(&PolicyConfig::AllOn("Swing-A100".into()), em.clone(), &systems);
        let rep = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions { include_idle_energy: true, ..Default::default() },
        );
        assert!(rep.idle_energy_j > 0.0);
        // recompute the complement from the report
        let mut want = 0.0;
        for (spec, tot) in systems.iter().zip(&rep.systems) {
            assert!(
                tot.busy_s <= rep.makespan_s * spec.count as f64 + 1e-9,
                "{}: busy {} vs capacity {}",
                spec.name,
                tot.busy_s,
                rep.makespan_s * spec.count as f64
            );
            want += spec.idle_w * (rep.makespan_s * spec.count as f64 - tot.busy_s).max(0.0);
        }
        assert!((rep.idle_energy_j - want).abs() <= 1e-6 * want.max(1.0));
    }

    #[test]
    fn batched_mode_amortizes_dispatch_energy() {
        // saturating arrivals on one system: bigger batches, fewer
        // dispatches, less total energy
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 40.0 }, 3).generate(400);
        let systems = system_catalog();
        let em = energy();
        let cfg = PolicyConfig::AllOn("Swing-A100".into());
        let mut p_serial = build_policy(&cfg, em.clone(), &systems);
        let serial = simulate(&queries, &systems, p_serial.as_mut(), &em, &SimOptions::default());
        let mut p_batched = build_policy(&cfg, em.clone(), &systems);
        let batched = simulate(
            &queries,
            &systems,
            p_batched.as_mut(),
            &em,
            &SimOptions {
                batching: Some(BatchingOptions::new(8, 0.25)),
                ..Default::default()
            },
        );
        // every query still served exactly once
        assert_eq!(batched.outcomes.len(), queries.len());
        let mut ids: Vec<u64> = batched.outcomes.iter().map(|o| o.query_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), queries.len());
        assert!(batched.energy_conserved(), "batched energy must still conserve");
        // fewer dispatches, real batches in the histogram
        assert!(batched.total_dispatches() < serial.total_dispatches());
        assert!(batched.mean_batch_size() > 1.2, "mean {}", batched.mean_batch_size());
        let hist = &batched.batches[SystemId::SWING_A100.0].size_hist;
        assert!(hist.len() > 1, "histogram must show batches beyond size 1: {hist:?}");
        // the amortization shows up in both components
        assert!(batched.dispatch_energy_j() < serial.dispatch_energy_j());
        assert!(batched.total_energy_j < serial.total_energy_j);
        // serial-equivalent energy of the same routing is what serial
        // mode actually spent (all queries on the A100 either way)
        assert!(batched.batching_energy_delta_j() > 0.0);
        assert!(serial.batching_energy_delta_j().abs() < 1e-6);
        // causality still holds for every member
        for o in &batched.outcomes {
            assert!(o.start_s >= o.arrival_s - 1e-9);
            assert!(o.finish_s >= o.start_s);
        }
    }

    #[test]
    fn linger_trades_latency_for_batching() {
        // sparse arrivals: without linger batches stay singletons; with a
        // generous linger the batcher waits and packs
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 2.0 }, 7).generate(120);
        let systems = system_catalog();
        let em = energy();
        let cfg = PolicyConfig::AllOn("Swing-A100".into());
        let run_with = |linger_s: f64| {
            let mut p = build_policy(&cfg, em.clone(), &systems);
            simulate(
                &queries,
                &systems,
                p.as_mut(),
                &em,
                &SimOptions {
                    batching: Some(BatchingOptions::new(8, linger_s)),
                    ..Default::default()
                },
            )
        };
        let eager = run_with(0.0);
        let patient = run_with(2.0);
        assert!(patient.mean_batch_size() >= eager.mean_batch_size());
        assert!(patient.total_dispatches() <= eager.total_dispatches());
    }

    #[test]
    fn queue_model_parse_round_trips() {
        assert_eq!(QueueModel::parse("per-worker").unwrap(), QueueModel::PerWorker);
        assert_eq!(QueueModel::parse("per-class").unwrap(), QueueModel::PerClass);
        for q in [QueueModel::PerWorker, QueueModel::PerClass] {
            assert_eq!(QueueModel::parse(q.name()).unwrap(), q);
        }
        assert!(QueueModel::parse("shared").is_err());
        assert_eq!(QueueModel::default(), QueueModel::PerWorker);
    }

    /// Per-worker queues let a multi-node class start batches on every
    /// node concurrently: with 2 nodes and singleton batches, the first
    /// two arrivals must both start at t = 0 on distinct nodes, and the
    /// next pair queues behind them.
    #[test]
    fn per_worker_queues_run_nodes_in_parallel() {
        let mut systems = system_catalog();
        systems[1].count = 2;
        let em = energy();
        let queries: Vec<Query> = (0..4u64).map(|id| Query::new(id, 64, 32)).collect();
        let mut p = build_policy(&PolicyConfig::AllOn("Swing-A100".into()), em.clone(), &systems);
        let rep = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions {
                batching: Some(BatchingOptions::new(1, 0.0)),
                ..Default::default()
            },
        );
        assert_eq!(rep.outcomes.len(), 4);
        let starts: Vec<f64> = rep.outcomes.iter().map(|o| o.start_s).collect();
        assert_eq!(starts[0], 0.0);
        assert_eq!(starts[1], 0.0, "second node must take query 1 immediately");
        assert!(starts[2] > 0.0 && starts[3] > 0.0, "third and fourth queries must queue");
        // identical queries on identical nodes: the two backlogged
        // queries start together when their nodes free up
        assert_eq!(starts[2], starts[3]);
        assert!(rep.energy_conserved());
    }

    /// Multi-node batched simulation stays conservative under both queue
    /// layouts, with shape-aware formation in play: every query served
    /// exactly once, causality intact, energy conserved.
    #[test]
    fn multi_node_batched_invariants_under_both_queue_models() {
        let mut systems = system_catalog();
        systems[0].count = 2;
        systems[1].count = 3;
        let em = energy();
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 30.0 }, 17).generate(300);
        for queues in [QueueModel::PerWorker, QueueModel::PerClass] {
            let mut p = build_policy(&PolicyConfig::JoinShortestQueue, em.clone(), &systems);
            let rep = simulate(
                &queries,
                &systems,
                p.as_mut(),
                &em,
                &SimOptions {
                    batching: Some(
                        BatchingOptions::new(4, 0.1)
                            .with_formation(FormationPolicy::ShapeAware { n_bins: 4 })
                            .with_queues(queues),
                    ),
                    ..Default::default()
                },
            );
            assert_eq!(rep.outcomes.len(), queries.len(), "{}", queues.name());
            let mut ids: Vec<u64> = rep.outcomes.iter().map(|o| o.query_id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), queries.len(), "{}", queues.name());
            assert!(rep.energy_conserved(), "{}", queues.name());
            for o in &rep.outcomes {
                assert!(o.start_s >= o.arrival_s - 1e-9, "{}", queues.name());
                assert!(o.finish_s >= o.start_s, "{}", queues.name());
            }
        }
    }

    /// `simulate` and `simulate_with_table` over a shared table are the
    /// same computation.
    #[test]
    fn table_reuse_is_equivalent() {
        let systems = system_catalog();
        let em = energy();
        let queries = AlpacaModel::default().trace(8, 2_000);
        let table = CostTable::build(&queries, &systems, &em);
        let cfg = PolicyConfig::Cost { lambda: 1.0 };
        let mut p1 = build_policy(&cfg, em.clone(), &systems);
        let direct = simulate(&queries, &systems, p1.as_mut(), &em, &SimOptions::default());
        let mut p2 = build_policy(&cfg, em.clone(), &systems);
        let shared =
            simulate_with_table(&queries, &systems, p2.as_mut(), &table, &SimOptions::default());
        assert_eq!(direct.total_energy_j, shared.total_energy_j);
        assert_eq!(direct.total_service_s, shared.total_service_s);
        assert_eq!(direct.makespan_s, shared.makespan_s);
        assert_eq!(direct.routing_counts(), shared.routing_counts());
    }

    /// The event-heap engine and the retained scan loop are the same
    /// computation, bit for bit (the exhaustive randomized pin lives in
    /// `rust/tests/properties.rs`; this is the fast deterministic
    /// version that runs in every tier-1 pass).
    #[test]
    fn event_heap_matches_scan_engine() {
        let mut systems = system_catalog();
        systems[1].count = 2;
        let em = energy();
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 35.0 }, 11).generate(400);
        let table = CostTable::build(&queries, &systems, &em);
        for (formation, queues) in [
            (FormationPolicy::FifoPrefix, QueueModel::PerWorker),
            (FormationPolicy::ShapeAware { n_bins: 4 }, QueueModel::PerWorker),
            (FormationPolicy::ShapeAware { n_bins: 4 }, QueueModel::PerClass),
        ] {
            let opts = SimOptions {
                include_idle_energy: true,
                batching: Some(
                    BatchingOptions::new(6, 0.15)
                        .with_formation(formation)
                        .with_queues(queues),
                ),
                ..Default::default()
            };
            let batch_table = BatchTable::new(em.clone(), &systems);
            let cfg = PolicyConfig::Cost { lambda: 1.0 };
            let mut p1 = build_policy(&cfg, em.clone(), &systems);
            let heap = simulate_batched_with_tables(
                &queries,
                &systems,
                p1.as_mut(),
                &table,
                &batch_table,
                &opts,
            );
            let mut p2 = build_policy(&cfg, em.clone(), &systems);
            let scan = simulate_batched_with_tables_scan(
                &queries,
                &systems,
                p2.as_mut(),
                &table,
                &batch_table,
                &opts,
            );
            assert_eq!(heap.outcomes.len(), scan.outcomes.len());
            for (a, b) in heap.outcomes.iter().zip(&scan.outcomes) {
                assert_eq!(a.query_id, b.query_id);
                assert_eq!(a.system, b.system);
                assert_eq!(a.start_s.to_bits(), b.start_s.to_bits());
                assert_eq!(a.finish_s.to_bits(), b.finish_s.to_bits());
                assert_eq!(a.service_s.to_bits(), b.service_s.to_bits());
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
            }
            assert_eq!(heap.total_energy_j.to_bits(), scan.total_energy_j.to_bits());
            assert_eq!(heap.idle_energy_j.to_bits(), scan.idle_energy_j.to_bits());
            assert_eq!(heap.makespan_s.to_bits(), scan.makespan_s.to_bits());
            assert_eq!(heap.serial_energy_j.to_bits(), scan.serial_energy_j.to_bits());
            assert_eq!(heap.rerouted, scan.rerouted);
            for (a, b) in heap.batches.iter().zip(&scan.batches) {
                assert_eq!(a.dispatches, b.dispatches);
                assert_eq!(a.size_hist, b.size_hist);
            }
        }
    }

    use crate::sched::faults::{FaultConfig, RetryPolicy};

    fn crashy() -> FaultConfig {
        FaultConfig {
            mtbf_s: 40.0,
            mttr_s: 5.0,
            retry: RetryPolicy { max_attempts: 3, ..RetryPolicy::default() },
            ..FaultConfig::default()
        }
    }

    /// `Some(disabled config)` must be byte-for-byte the fault-free
    /// engines — the tentpole's pinning contract at its cheapest.
    #[test]
    fn disabled_fault_config_is_bit_identical_to_none() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 80.0 }, 11).generate(800);
        let systems = system_catalog();
        let em = energy();
        for batching in [None, Some(BatchingOptions::new(4, 0.05))] {
            let base_opts = SimOptions { batching, ..Default::default() };
            let opts = SimOptions { faults: Some(FaultConfig::default()), ..base_opts.clone() };
            assert!(!faults_live(&opts), "default FaultConfig must be disabled");
            let mut p1 = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let a = simulate(&queries, &systems, p1.as_mut(), &em, &base_opts);
            let mut p2 = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let b = simulate(&queries, &systems, p2.as_mut(), &em, &opts);
            assert_eq!(a.outcomes.len(), b.outcomes.len());
            for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
                assert_eq!(x.query_id, y.query_id);
                assert_eq!(x.system, y.system);
                assert_eq!(x.finish_s.to_bits(), y.finish_s.to_bits());
                assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits());
            }
            assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
            assert_eq!(b.total_retries(), 0);
            assert_eq!(b.wasted_energy_j.to_bits(), 0f64.to_bits());
        }
    }

    /// Conservation under crashes: every arrival is served or abandoned
    /// (u64-exact), energy balances once wasted joules are counted, and
    /// latencies span the retries.
    #[test]
    fn fault_conservation_serial_and_batched() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 60.0 }, 13).generate(1500);
        let systems = system_catalog();
        let em = energy();
        for batching in [None, Some(BatchingOptions::new(4, 0.05))] {
            let opts =
                SimOptions { batching, faults: Some(crashy()), ..Default::default() };
            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let r = simulate(&queries, &systems, p.as_mut(), &em, &opts);
            let arrived: u64 = r.shed.iter().map(|s| s.arrived).sum();
            assert_eq!(arrived, queries.len() as u64);
            assert_eq!(
                r.outcomes.len() as u64 + r.total_shed() + r.total_abandoned(),
                queries.len() as u64,
                "arrived == served + shed + abandoned"
            );
            assert!(
                r.total_retries() > 0,
                "a 40 s MTBF over a multi-minute trace must crash something"
            );
            assert!(r.wasted_energy_j > 0.0);
            assert!(r.energy_conserved(), "wasted joules must balance the energy ledger");
            for o in &r.outcomes {
                assert!(o.start_s >= o.arrival_s - 1e-9);
                assert!(o.finish_s >= o.start_s);
            }
            // outcomes stay unique per query even through retries
            let mut ids: Vec<u64> = r.outcomes.iter().map(|o| o.query_id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), r.outcomes.len(), "a retried query must be served once");
        }
    }

    /// Admission composes with faults: the ledger splits losses between
    /// shed (refused at the door) and abandoned (crashed out of
    /// retries), and conservation still holds.
    #[test]
    fn fault_with_admission_conserves() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 300.0 }, 17).generate(1200);
        let systems = system_catalog();
        let em = energy();
        let adm = AdmissionConfig { queue_budget: 8, ..AdmissionConfig::default() };
        let opts = SimOptions {
            admission: Some(adm),
            faults: Some(crashy()),
            ..Default::default()
        };
        let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let r = simulate(&queries, &systems, p.as_mut(), &em, &opts);
        let arrived: u64 = r.shed.iter().map(|s| s.arrived).sum();
        assert_eq!(arrived, queries.len() as u64);
        assert_eq!(
            r.outcomes.len() as u64 + r.total_shed() + r.total_abandoned(),
            queries.len() as u64
        );
        assert!(r.total_shed() > 0, "300 q/s into an 8-deep budget must shed");
        assert!(r.energy_conserved());
    }

    /// Slowdown-only faults stretch runtime and energy but lose nothing:
    /// served == arrived, zero retries, zero waste, and total energy is
    /// strictly above the fault-free run.
    #[test]
    fn slowdowns_stretch_energy_without_losing_queries() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 40.0 }, 19).generate(600);
        let systems = system_catalog();
        let em = energy();
        // Dense onsets relative to the ~15 s arrival span so every node
        // sees at least one slowdown window during the run.
        let slow = FaultConfig {
            slow_mtbf_s: 2.0,
            slow_duration_s: 20.0,
            slow_factor: 3.0,
            ..FaultConfig::default()
        };
        let opts = SimOptions { faults: Some(slow), ..Default::default() };
        let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let r = simulate(&queries, &systems, p.as_mut(), &em, &opts);
        assert_eq!(r.outcomes.len(), queries.len());
        assert_eq!(r.total_retries(), 0);
        assert_eq!(r.wasted_energy_j.to_bits(), 0f64.to_bits());
        assert!(r.energy_conserved());
        let mut p2 = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let base = simulate(&queries, &systems, p2.as_mut(), &em, &SimOptions::default());
        assert!(
            r.total_energy_j > base.total_energy_j,
            "a 3x slowdown window must burn extra joules"
        );
    }
}
