//! The simulation engine: trace × policy × cluster → SimReport.
//!
//! Two modes, matching the paper:
//! - **batch** (the paper's Eq. 9/10 analysis): assignments don't
//!   interact; each query is charged its standalone `R`/`E` and nodes
//!   serialize FIFO per system. Arrivals are all at t=0.
//! - **online**: queries arrive over time; the policy sees live queue
//!   state (enabling queue-aware extensions the paper speculates about).
//!
//! Infeasible assignments (policy sent an OOM query somewhere) are
//! re-routed to the cheapest feasible system and counted in
//! `SimOptions::strict` mode as errors.

use super::cluster::ClusterState;
use super::report::{QueryOutcome, SimReport, SystemTotals};
use crate::hw::spec::SystemSpec;
use crate::perf::energy::EnergyModel;
use crate::perf::model::Feasibility;
use crate::sched::policy::{ClusterView, Policy};
use crate::workload::Query;

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// charge idle-floor energy of all nodes across the makespan
    pub include_idle_energy: bool,
    /// panic if the policy picks an infeasible system (tests); otherwise
    /// fall back to the cheapest feasible one
    pub strict: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self { include_idle_energy: false, strict: false }
    }
}

/// Run the simulation. Queries must be sorted by arrival time (batch
/// traces trivially are).
pub fn simulate(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    energy: &EnergyModel,
    opts: &SimOptions,
) -> SimReport {
    debug_assert!(
        queries.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
        "queries must be sorted by arrival"
    );
    let mut cluster = ClusterState::new(systems);
    let mut outcomes = Vec::with_capacity(queries.len());
    let mut sys_energy = vec![0.0f64; systems.len()];

    for q in queries {
        let (m, n) = (q.input_tokens, q.output_tokens);
        // advance queue-depth estimates to the arrival instant
        let depths: Vec<f64> = cluster
            .nodes
            .iter()
            .map(|node| {
                node.node_free_at
                    .iter()
                    .map(|&f| (f - q.arrival_s).max(0.0))
                    .sum::<f64>()
            })
            .collect();
        let lens = cluster.queue_lens();
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let mut sid = policy.assign(q, &view);
        assert!(sid.0 < systems.len(), "policy returned out-of-range system");

        if energy.perf.feasibility(&systems[sid.0], m, n) != Feasibility::Ok {
            if opts.strict {
                panic!(
                    "policy '{}' routed infeasible query (m={m}, n={n}) to {}",
                    policy.name(),
                    systems[sid.0].name
                );
            }
            // fall back: cheapest feasible system
            let mut best = None;
            let mut best_e = f64::INFINITY;
            for (i, spec) in systems.iter().enumerate() {
                if energy.perf.feasibility(spec, m, n) == Feasibility::Ok {
                    let e = energy.energy(spec, m, n);
                    if e < best_e {
                        best_e = e;
                        best = Some(i);
                    }
                }
            }
            sid = crate::hw::catalog::SystemId(
                best.unwrap_or_else(|| panic!("query (m={m},n={n}) feasible nowhere")),
            );
        }

        let spec = &systems[sid.0];
        let service = energy.runtime(spec, m, n);
        let e_j = energy.energy(spec, m, n);
        let node = cluster.get_mut(sid);
        let (start, finish) = node.schedule(q.arrival_s, service);
        node.energy_j += e_j;
        node.queue_depth_s = node.node_free_at.iter().map(|&f| (f - q.arrival_s).max(0.0)).sum();
        node.queue_len += 1;
        sys_energy[sid.0] += e_j;
        outcomes.push(QueryOutcome {
            query_id: q.id,
            system: sid.0,
            arrival_s: q.arrival_s,
            start_s: start,
            finish_s: finish,
            service_s: service,
            energy_j: e_j,
        });
    }

    let makespan = cluster.makespan();
    let idle_energy: f64 = if opts.include_idle_energy {
        systems
            .iter()
            .zip(&cluster.nodes)
            .map(|(s, node)| s.idle_w * (makespan * s.count as f64 - node.busy_s).max(0.0))
            .sum()
    } else {
        0.0
    };

    let total_service: f64 = outcomes.iter().map(|o| o.service_s).sum();
    let total_energy: f64 = sys_energy.iter().sum::<f64>() + idle_energy;

    SimReport {
        policy: policy.name(),
        systems: cluster
            .nodes
            .iter()
            .map(|n| SystemTotals {
                name: n.spec.name.to_string(),
                queries: n.queries,
                busy_s: n.busy_s,
                energy_j: n.energy_j,
            })
            .collect(),
        outcomes,
        makespan_s: makespan,
        total_service_s: total_service,
        total_energy_j: total_energy,
        idle_energy_j: idle_energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::PolicyConfig;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::model::PerfModel;
    use crate::sched::policy::build_policy;
    use crate::workload::alpaca::AlpacaModel;

    fn energy() -> EnergyModel {
        EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()))
    }

    fn run(policy_cfg: PolicyConfig, queries: &[Query]) -> SimReport {
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&policy_cfg, em.clone(), &systems);
        simulate(queries, &systems, p.as_mut(), &em, &SimOptions::default())
    }

    #[test]
    fn every_query_processed_exactly_once() {
        // Eq. 3–4: partition property
        let queries = AlpacaModel::default().trace(3, 5000);
        let r = run(PolicyConfig::RoundRobin, &queries);
        assert_eq!(r.outcomes.len(), queries.len());
        let mut ids: Vec<u64> = r.outcomes.iter().map(|o| o.query_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), queries.len());
        assert_eq!(r.routing_counts().iter().sum::<u64>(), queries.len() as u64);
    }

    #[test]
    fn energy_conservation() {
        let queries = AlpacaModel::default().trace(4, 3000);
        for cfg in [
            PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
            PolicyConfig::Cost { lambda: 1.0 },
            PolicyConfig::AllOn("Swing-A100".into()),
        ] {
            let r = run(cfg, &queries);
            assert!(r.energy_conserved(), "{}", r.policy);
        }
    }

    #[test]
    fn hybrid_threshold_saves_energy_vs_all_a100() {
        // the paper's headline mechanism, end-to-end through the sim
        let queries = AlpacaModel::default().trace(2024, 20_000);
        let hybrid = run(
            PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
            &queries,
        );
        let baseline = run(PolicyConfig::AllOn("Swing-A100".into()), &queries);
        let saving = 1.0 - hybrid.total_energy_j / baseline.total_energy_j;
        assert!(
            (0.005..=0.20).contains(&saving),
            "hybrid saving {:.1}% outside plausible band",
            saving * 100.0
        );
        // but costs runtime (paper §6.3's stated trade-off)
        assert!(hybrid.total_service_s > baseline.total_service_s);
    }

    #[test]
    fn infeasible_fallback_rescues_queries() {
        // all-on-M1 with big generations → fallback must reroute
        let queries = vec![Query::new(0, 8, 4096), Query::new(1, 8, 8)];
        let r = run(PolicyConfig::AllOn("M1-Pro".into()), &queries);
        assert_eq!(r.outcomes.len(), 2);
        // the 4096-generation query cannot have run on the M1
        let big = r.outcomes.iter().find(|o| o.query_id == 0).unwrap();
        assert_ne!(big.system, 0);
        let small = r.outcomes.iter().find(|o| o.query_id == 1).unwrap();
        assert_eq!(small.system, 0);
    }

    #[test]
    #[should_panic(expected = "routed infeasible")]
    fn strict_mode_panics_on_infeasible() {
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::AllOn("M1-Pro".into()), em.clone(), &systems);
        let queries = vec![Query::new(0, 8, 4096)];
        simulate(&queries, &systems, p.as_mut(), &em, &SimOptions { strict: true, ..Default::default() });
    }

    #[test]
    fn online_arrivals_queue_properly() {
        use crate::workload::generator::{Arrival, TraceGenerator};
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 50.0 }, 5).generate(500);
        let r = run(PolicyConfig::JoinShortestQueue, &queries);
        // starts never precede arrivals; finishes never precede starts
        for o in &r.outcomes {
            assert!(o.start_s >= o.arrival_s - 1e-9);
            assert!(o.finish_s >= o.start_s);
        }
        // under load, someone must have waited
        assert!(r.outcomes.iter().any(|o| o.queue_wait_s() > 0.0));
    }

    #[test]
    fn idle_energy_accounting() {
        let queries = AlpacaModel::default().trace(6, 200);
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::AllOn("Swing-A100".into()), em.clone(), &systems);
        let with_idle = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions { include_idle_energy: true, ..Default::default() },
        );
        assert!(with_idle.idle_energy_j > 0.0);
        assert!(with_idle.total_energy_j > with_idle.systems.iter().map(|s| s.energy_j).sum::<f64>());
    }
}
