//! The simulation engine: trace × policy × cluster → SimReport.
//!
//! Two modes, matching the paper:
//! - **batch** (the paper's Eq. 9/10 analysis): assignments don't
//!   interact; each query is charged its standalone `R`/`E` and nodes
//!   serialize FIFO per system. Arrivals are all at t=0.
//! - **online**: queries arrive over time; the policy sees live queue
//!   state (enabling queue-aware extensions the paper speculates about).
//!   Queue state is derived from `node_free_at` at each arrival instant
//!   — both `queue_depth_s` and `queue_len` drain as work completes.
//!
//! Per-query costs come from a [`CostTable`] built once per trace
//! ([`simulate`] builds it; [`simulate_with_table`] reuses a shared one
//! across a sweep grid — see [`crate::experiments::runner`]).
//!
//! Infeasible assignments (policy sent an OOM query somewhere) panic in
//! [`SimOptions::strict`] mode; otherwise they are re-routed to the
//! cheapest feasible system and counted in [`SimReport::rerouted`].

use super::cluster::ClusterState;
use super::report::{QueryOutcome, SimReport, SystemTotals};
use crate::hw::catalog::SystemId;
use crate::hw::spec::SystemSpec;
use crate::perf::cost_table::CostTable;
use crate::perf::energy::EnergyModel;
use crate::perf::model::Feasibility;
use crate::sched::policy::{ClusterView, Policy};
use crate::workload::Query;

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// charge idle-floor energy of all nodes across the makespan
    pub include_idle_energy: bool,
    /// panic if the policy picks an infeasible system (tests); otherwise
    /// fall back to the cheapest feasible one and count it in
    /// [`SimReport::rerouted`]
    pub strict: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self { include_idle_energy: false, strict: false }
    }
}

/// Run the simulation, evaluating the perf/energy model through a
/// freshly built [`CostTable`]. Queries must be sorted by arrival time
/// (batch traces trivially are).
pub fn simulate(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    energy: &EnergyModel,
    opts: &SimOptions,
) -> SimReport {
    let table = CostTable::build(queries, systems, energy);
    simulate_with_table(queries, systems, policy, &table, opts)
}

/// Run the simulation against a prebuilt [`CostTable`] (row `i` must
/// describe `queries[i]` over exactly `systems`). Sweeps that replay the
/// same trace under many policies / grid points build the table once and
/// call this per point.
pub fn simulate_with_table(
    queries: &[Query],
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    table: &CostTable,
    opts: &SimOptions,
) -> SimReport {
    debug_assert!(
        queries.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s),
        "queries must be sorted by arrival"
    );
    assert_eq!(table.n_queries(), queries.len(), "cost table rows must match the trace");
    assert_eq!(table.n_systems(), systems.len(), "cost table columns must match the cluster");
    let mut cluster = ClusterState::new(systems);
    let mut outcomes = Vec::with_capacity(queries.len());
    let mut sys_energy = vec![0.0f64; systems.len()];
    let mut rerouted = 0u64;

    for (qi, q) in queries.iter().enumerate() {
        let (m, n) = (q.input_tokens, q.output_tokens);
        // retire finished work, then view queue state at the arrival
        // instant — the policy sees live depths *and* live lengths
        cluster.advance_to(q.arrival_s);
        let depths = cluster.queue_depths_at(q.arrival_s);
        let lens = cluster.queue_lens();
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let mut sid = policy.assign(q, &view);
        assert!(sid.0 < systems.len(), "policy returned out-of-range system");

        if table.feasibility(qi, sid.0) != Feasibility::Ok {
            if opts.strict {
                panic!(
                    "policy '{}' routed infeasible query (m={m}, n={n}) to {}",
                    policy.name(),
                    systems[sid.0].name
                );
            }
            // fall back: cheapest feasible system
            sid = SystemId(
                table
                    .cheapest_feasible(qi)
                    .unwrap_or_else(|| panic!("query (m={m},n={n}) feasible nowhere")),
            );
            rerouted += 1;
        }

        let service = table.runtime_s(qi, sid.0);
        let e_j = table.energy_j(qi, sid.0);
        let node = cluster.get_mut(sid);
        let (start, finish) = node.schedule(q.arrival_s, service);
        node.energy_j += e_j;
        sys_energy[sid.0] += e_j;
        outcomes.push(QueryOutcome {
            query_id: q.id,
            system: sid.0,
            arrival_s: q.arrival_s,
            start_s: start,
            finish_s: finish,
            service_s: service,
            energy_j: e_j,
        });
    }

    let makespan = cluster.makespan();
    let idle_energy: f64 = if opts.include_idle_energy {
        systems
            .iter()
            .zip(&cluster.nodes)
            .map(|(s, node)| s.idle_w * (makespan * s.count as f64 - node.busy_s).max(0.0))
            .sum()
    } else {
        0.0
    };

    let total_service: f64 = outcomes.iter().map(|o| o.service_s).sum();
    let total_energy: f64 = sys_energy.iter().sum::<f64>() + idle_energy;

    SimReport {
        policy: policy.name(),
        systems: cluster
            .nodes
            .iter()
            .map(|n| SystemTotals {
                name: n.spec.name.to_string(),
                queries: n.queries,
                busy_s: n.busy_s,
                energy_j: n.energy_j,
            })
            .collect(),
        outcomes,
        makespan_s: makespan,
        total_service_s: total_service,
        total_energy_j: total_energy,
        idle_energy_j: idle_energy,
        rerouted,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::PolicyConfig;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::model::PerfModel;
    use crate::sched::policy::build_policy;
    use crate::workload::alpaca::AlpacaModel;

    fn energy() -> EnergyModel {
        EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()))
    }

    fn run(policy_cfg: PolicyConfig, queries: &[Query]) -> SimReport {
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&policy_cfg, em.clone(), &systems);
        simulate(queries, &systems, p.as_mut(), &em, &SimOptions::default())
    }

    #[test]
    fn every_query_processed_exactly_once() {
        // Eq. 3–4: partition property
        let queries = AlpacaModel::default().trace(3, 5000);
        let r = run(PolicyConfig::RoundRobin, &queries);
        assert_eq!(r.outcomes.len(), queries.len());
        let mut ids: Vec<u64> = r.outcomes.iter().map(|o| o.query_id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), queries.len());
        assert_eq!(r.routing_counts().iter().sum::<u64>(), queries.len() as u64);
    }

    #[test]
    fn energy_conservation() {
        let queries = AlpacaModel::default().trace(4, 3000);
        for cfg in [
            PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
            PolicyConfig::Cost { lambda: 1.0 },
            PolicyConfig::AllOn("Swing-A100".into()),
        ] {
            let r = run(cfg, &queries);
            assert!(r.energy_conserved(), "{}", r.policy);
        }
    }

    #[test]
    fn hybrid_threshold_saves_energy_vs_all_a100() {
        // the paper's headline mechanism, end-to-end through the sim
        let queries = AlpacaModel::default().trace(2024, 20_000);
        let hybrid = run(
            PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
            &queries,
        );
        let baseline = run(PolicyConfig::AllOn("Swing-A100".into()), &queries);
        let saving = 1.0 - hybrid.total_energy_j / baseline.total_energy_j;
        assert!(
            (0.005..=0.20).contains(&saving),
            "hybrid saving {:.1}% outside plausible band",
            saving * 100.0
        );
        // but costs runtime (paper §6.3's stated trade-off)
        assert!(hybrid.total_service_s > baseline.total_service_s);
    }

    #[test]
    fn infeasible_fallback_rescues_queries() {
        // all-on-M1 with big generations → fallback must reroute
        let queries = vec![Query::new(0, 8, 4096), Query::new(1, 8, 8)];
        let r = run(PolicyConfig::AllOn("M1-Pro".into()), &queries);
        assert_eq!(r.outcomes.len(), 2);
        // the 4096-generation query cannot have run on the M1
        let big = r.outcomes.iter().find(|o| o.query_id == 0).unwrap();
        assert_ne!(big.system, 0);
        let small = r.outcomes.iter().find(|o| o.query_id == 1).unwrap();
        assert_eq!(small.system, 0);
        // the fallback is visible in the report
        assert_eq!(r.rerouted, 1);
    }

    #[test]
    #[should_panic(expected = "routed infeasible")]
    fn strict_mode_panics_on_infeasible() {
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::AllOn("M1-Pro".into()), em.clone(), &systems);
        let queries = vec![Query::new(0, 8, 4096)];
        simulate(&queries, &systems, p.as_mut(), &em, &SimOptions { strict: true, ..Default::default() });
    }

    #[test]
    fn online_arrivals_queue_properly() {
        use crate::workload::generator::{Arrival, TraceGenerator};
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 50.0 }, 5).generate(500);
        let r = run(PolicyConfig::JoinShortestQueue, &queries);
        // starts never precede arrivals; finishes never precede starts
        for o in &r.outcomes {
            assert!(o.start_s >= o.arrival_s - 1e-9);
            assert!(o.finish_s >= o.start_s);
        }
        // under load, someone must have waited
        assert!(r.outcomes.iter().any(|o| o.queue_wait_s() > 0.0));
        // a feasible-everywhere workload never triggers the fallback
        assert_eq!(r.rerouted, 0);
    }

    #[test]
    fn idle_energy_accounting() {
        let queries = AlpacaModel::default().trace(6, 200);
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::AllOn("Swing-A100".into()), em.clone(), &systems);
        let with_idle = simulate(
            &queries,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions { include_idle_energy: true, ..Default::default() },
        );
        assert!(with_idle.idle_energy_j > 0.0);
        assert!(with_idle.total_energy_j > with_idle.systems.iter().map(|s| s.energy_j).sum::<f64>());
    }

    /// A probe that routes like JSQ-by-length and records every view it
    /// was shown — the regression instrument for the stale-queue bug.
    struct LenJsqProbe {
        seen_lens: Vec<Vec<usize>>,
        seen_depths: Vec<Vec<f64>>,
    }

    impl Policy for LenJsqProbe {
        fn name(&self) -> String {
            "len-jsq-probe".into()
        }

        fn assign(&mut self, _q: &Query, view: &ClusterView) -> SystemId {
            self.seen_lens.push(view.queue_len.to_vec());
            self.seen_depths.push(view.queue_depth_s.to_vec());
            let best = view
                .queue_len
                .iter()
                .enumerate()
                .min_by_key(|&(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap_or(0);
            SystemId(best)
        }
    }

    /// Regression for the seed bug where `queue_len` was only ever
    /// incremented: a queue-length JSQ on a *drained* cluster must route
    /// exactly like a fresh cluster, because the view's lengths (and
    /// depths) must have fallen back to zero.
    #[test]
    fn drained_cluster_routes_like_fresh_cluster() {
        let systems = system_catalog();
        let em = energy();
        // a burst at t=0 followed by one arrival long after everything
        // finished (Alpaca service times are far below 1e6 s)
        let mut queries: Vec<Query> = (0..50u64).map(|id| Query::new(id, 64, 64)).collect();
        let mut late = Query::new(50, 64, 64);
        late.arrival_s = 1.0e6;
        queries.push(late);

        let mut probe = LenJsqProbe { seen_lens: Vec::new(), seen_depths: Vec::new() };
        let drained =
            simulate(&queries, &systems, &mut probe, &em, &SimOptions::default());
        // mid-burst the probe must have seen non-zero backlog...
        assert!(
            probe.seen_lens.iter().any(|lens| lens.iter().any(|&l| l > 0)),
            "burst never surfaced in queue_len — view is not live"
        );
        // ...but the drained arrival sees an all-zero view, exactly like
        // the first query of a fresh simulation
        let last_lens = probe.seen_lens.last().unwrap();
        let last_depths = probe.seen_depths.last().unwrap();
        assert!(last_lens.iter().all(|&l| l == 0), "stale queue_len: {last_lens:?}");
        assert!(last_depths.iter().all(|&d| d == 0.0), "stale depth: {last_depths:?}");
        assert_eq!(probe.seen_lens.first().unwrap(), last_lens);

        // and the routing decision matches a fresh cluster's first query
        let mut fresh_probe = LenJsqProbe { seen_lens: Vec::new(), seen_depths: Vec::new() };
        let fresh = simulate(
            &[Query::new(0, 64, 64)],
            &systems,
            &mut fresh_probe,
            &em,
            &SimOptions::default(),
        );
        assert_eq!(
            drained.outcomes.last().unwrap().system,
            fresh.outcomes[0].system,
            "drained cluster must route like a fresh cluster"
        );
    }

    /// The built-in (depth-based) JSQ agrees between drained and fresh
    /// clusters end-to-end through `build_policy`.
    #[test]
    fn jsq_on_drained_cluster_matches_fresh() {
        let systems = system_catalog();
        let em = energy();
        let mut queries: Vec<Query> = (0..30u64).map(|id| Query::new(id, 128, 32)).collect();
        let mut late = Query::new(30, 128, 32);
        late.arrival_s = 1.0e6;
        queries.push(late);
        let mut p = build_policy(&PolicyConfig::JoinShortestQueue, em.clone(), &systems);
        let drained = simulate(&queries, &systems, p.as_mut(), &em, &SimOptions::default());
        let mut p2 = build_policy(&PolicyConfig::JoinShortestQueue, em.clone(), &systems);
        let fresh = simulate(
            &[Query::new(0, 128, 32)],
            &systems,
            p2.as_mut(),
            &em,
            &SimOptions::default(),
        );
        assert_eq!(drained.outcomes.last().unwrap().system, fresh.outcomes[0].system);
    }

    /// `simulate` and `simulate_with_table` over a shared table are the
    /// same computation.
    #[test]
    fn table_reuse_is_equivalent() {
        let systems = system_catalog();
        let em = energy();
        let queries = AlpacaModel::default().trace(8, 2_000);
        let table = CostTable::build(&queries, &systems, &em);
        let cfg = PolicyConfig::Cost { lambda: 1.0 };
        let mut p1 = build_policy(&cfg, em.clone(), &systems);
        let direct = simulate(&queries, &systems, p1.as_mut(), &em, &SimOptions::default());
        let mut p2 = build_policy(&cfg, em.clone(), &systems);
        let shared =
            simulate_with_table(&queries, &systems, p2.as_mut(), &table, &SimOptions::default());
        assert_eq!(direct.total_energy_j, shared.total_energy_j);
        assert_eq!(direct.total_service_s, shared.total_service_s);
        assert_eq!(direct.makespan_s, shared.makespan_s);
        assert_eq!(direct.routing_counts(), shared.routing_counts());
    }
}
