//! Analytic queueing estimates (M/M/c) used to cross-validate the
//! discrete-event simulator and to size fleets without simulating.
//!
//! The sim is ground truth; these closed forms give the sanity rails:
//! utilization ρ, Erlang-C wait probability, and mean waiting time. A
//! test drives both on the same Poisson workload and checks agreement.

/// M/M/c steady-state results.
#[derive(Clone, Copy, Debug)]
pub struct MmcResult {
    /// offered load a = λ/µ (erlangs)
    pub offered: f64,
    /// per-server utilization ρ = a/c
    pub rho: f64,
    /// probability an arrival waits (Erlang-C)
    pub p_wait: f64,
    /// mean wait in queue (s)
    pub wq_s: f64,
    /// mean time in system (s)
    pub w_s: f64,
}

/// Solve M/M/c for arrival rate `lambda` (1/s), mean service time
/// `service_s`, and `c` servers. Returns None when unstable (ρ ≥ 1).
pub fn mmc(lambda: f64, service_s: f64, c: usize) -> Option<MmcResult> {
    assert!(lambda > 0.0 && service_s > 0.0 && c > 0);
    let mu = 1.0 / service_s;
    let a = lambda / mu;
    let rho = a / c as f64;
    if rho >= 1.0 {
        return None;
    }
    // Erlang C via the numerically stable iterative form
    let mut inv_b = 1.0; // Erlang-B inverse, B(0, a) = 1
    for k in 1..=c {
        inv_b = 1.0 + inv_b * k as f64 / a;
    }
    let b = 1.0 / inv_b;
    let p_wait = b / (1.0 - rho * (1.0 - b));
    let wq = p_wait * service_s / (c as f64 * (1.0 - rho));
    Some(MmcResult { offered: a, rho, p_wait, wq_s: wq, w_s: wq + service_s })
}

/// Minimum servers for target mean wait (fleet sizing helper).
pub fn servers_for_wait(lambda: f64, service_s: f64, max_wq_s: f64) -> usize {
    for c in 1..=4096 {
        if let Some(r) = mmc(lambda, service_s, c) {
            if r.wq_s <= max_wq_s {
                return c;
            }
        }
    }
    4096
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm1_closed_form() {
        // M/M/1: Wq = ρ/(µ−λ); λ=0.5, µ=1 → Wq = 1.0
        let r = mmc(0.5, 1.0, 1).unwrap();
        assert!((r.rho - 0.5).abs() < 1e-12);
        assert!((r.p_wait - 0.5).abs() < 1e-12); // P(wait) = ρ for M/M/1
        assert!((r.wq_s - 1.0).abs() < 1e-9);
        assert!((r.w_s - 2.0).abs() < 1e-9);
    }

    #[test]
    fn instability_detected() {
        assert!(mmc(2.0, 1.0, 1).is_none());
        assert!(mmc(2.0, 1.0, 2).is_none()); // ρ = 1 exactly
        assert!(mmc(2.0, 1.0, 3).is_some());
    }

    #[test]
    fn more_servers_less_wait() {
        let w2 = mmc(1.5, 1.0, 2).unwrap().wq_s;
        let w4 = mmc(1.5, 1.0, 4).unwrap().wq_s;
        let w8 = mmc(1.5, 1.0, 8).unwrap().wq_s;
        assert!(w2 > w4 && w4 > w8);
    }

    #[test]
    fn sizing_helper_meets_target() {
        let c = servers_for_wait(10.0, 1.0, 0.1);
        let r = mmc(10.0, 1.0, c).unwrap();
        assert!(r.wq_s <= 0.1);
        if c > 1 {
            // c−1 must miss the target (minimality)
            match mmc(10.0, 1.0, c - 1) {
                Some(r2) => assert!(r2.wq_s > 0.1),
                None => {} // unstable — also a miss
            }
        }
    }

    /// Cross-validation: discrete-event sim ≈ M/M/1 on an exponential-ish
    /// workload. We can't get exponential service exactly (service times
    /// come from the perf model), so this uses a single-system cluster
    /// with near-constant service (M/D/1) and checks the sim's wait lies
    /// between the M/D/1 and M/M/1 predictions (M/D/1 = half M/M/1).
    #[test]
    fn sim_wait_bracketed_by_queueing_theory() {
        use crate::config::schema::PolicyConfig;
        use crate::hw::catalog::system_catalog;
        use crate::model::llm_catalog;
        use crate::perf::energy::EnergyModel;
        use crate::perf::model::PerfModel;
        use crate::sched::policy::build_policy;
        use crate::sim::engine::{simulate, SimOptions};
        use crate::workload::generator::{Arrival, TraceGenerator};
        use crate::workload::Query;

        let systems = vec![system_catalog()[1].clone()]; // A100 only
        let em = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        // constant-size queries → deterministic service
        let service = em.runtime(&systems[0], 32, 32);
        let rho_target = 0.7;
        let rate = rho_target / service;
        let mut queries: Vec<Query> = TraceGenerator::new(Arrival::Poisson { rate }, 3)
            .generate(20_000)
            .into_iter()
            .map(|q| Query { input_tokens: 32, output_tokens: 32, ..q })
            .collect();
        queries.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
        let mut p = build_policy(&PolicyConfig::AllOn("Swing-A100".into()), em.clone(), &systems);
        let rep = simulate(&queries, &systems, p.as_mut(), &em, &SimOptions::default());
        let sim_wq: f64 =
            rep.outcomes.iter().map(|o| o.queue_wait_s()).sum::<f64>() / rep.outcomes.len() as f64;

        let mm1 = mmc(rate, service, 1).unwrap().wq_s;
        let md1 = mm1 / 2.0;
        assert!(
            sim_wq > md1 * 0.8 && sim_wq < mm1 * 1.2,
            "sim Wq {sim_wq:.3} outside [M/D/1 {md1:.3}, M/M/1 {mm1:.3}] bracket"
        );
    }
}
