//! Streaming simulation: million-query runs in bounded memory.
//!
//! The materialized engines ([`crate::sim::engine`]) hold the whole
//! trace, a [`crate::perf::cost_table::CostTable`] row per query, and
//! every [`QueryOutcome`] until the end of the run — three O(n) buffers
//! that put a 10⁷-query study out of reach. This module runs the *same*
//! simulation over a [`QuerySource`], holding only:
//!
//! - the queries currently resident — in virtual worker queues or in
//!   flight on a node (O(pending); a one-query lookahead on the source
//!   is the entire arrival buffer);
//! - one lazily evaluated cost row per **unique** `(m, n)` shape
//!   ([`RowCache`] — the dedup observation that makes
//!   [`crate::perf::cost_table::CostTable::build_dedup`] cheap, applied
//!   online);
//! - streaming outcome accumulators ([`StreamingOutcomes`]: running
//!   sums, a P² p99 estimator, and an O(in-flight) reorder buffer that
//!   reproduces the materialized engines' trace-order float sums
//!   bit-for-bit).
//!
//! Dispatch uses the same event-heap core as the materialized batched
//! engine — per-queue [`DueEvent`]s with lazy stamp invalidation — and
//! every routing, formation, trimming, scheduling, and attribution step
//! mirrors `engine.rs` expression-for-expression, so a streaming run
//! over [`crate::workload::source::SliceSource`] is **bit-identical**
//! to the materialized run on the same trace (per-outcome fields,
//! makespan, system totals, trace-order sums — pinned by
//! `rust/tests/stream_sim.rs`). What the streaming report gives up is
//! only what fundamentally needs the full outcome vector: the exact p99
//! becomes a P² estimate, and per-query outcomes flow through the sink
//! callback instead of a returned `Vec`.
//!
//! One caveat worth knowing: batched mode memoizes batch compositions
//! in a [`BatchTable`], whose exact-key cache grows with the number of
//! *distinct* compositions encountered — heavy-tailed traces keep
//! minting new ones. Serial mode (`opts.batching = None`) is strictly
//! O(pending + unique shapes) and is what the CI bounded-memory smoke
//! test runs.

use super::cluster::{ClusterState, NodeState};
use super::continuous::{episode_energy, Episode, LiveMember};
use super::engine::{faults_live, BatchMode, BatchingOptions, DueEvent, QueueModel, SimOptions};
use super::report::{
    BatchStats, QueryOutcome, ShedLedger, ShedStats, StreamingOutcomes, SystemTotals,
};
use crate::hw::catalog::SystemId;
use crate::hw::spec::SystemSpec;
use crate::perf::cost_table::{BatchTable, RowCache};
use crate::perf::energy::EnergyModel;
use crate::sched::admission;
use crate::sched::faults::{FaultState, RetryAttempt};
use crate::sched::overload::{AdmitDecision, OverloadPolicy};
use crate::sched::formation::{FormationPolicy, FormationScratch, SortedWindow};
use crate::sched::policy::{ClusterView, Policy};
use crate::workload::source::QuerySource;
use crate::workload::Query;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

/// What a streaming run reports: everything [`crate::sim::SimReport`]
/// derives without its outcome vector, computed from running
/// accumulators. Fields named like their `SimReport` counterparts are
/// bit-identical to them on the same trace (the p99 is the P² estimate,
/// the means accumulate in completion order — those two are
/// approximate).
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub policy: String,
    /// queries simulated (the source may end before the requested limit)
    pub queries: u64,
    pub systems: Vec<SystemTotals>,
    pub makespan_s: f64,
    /// Σ per-query service time, accumulated in trace order —
    /// bit-identical to [`crate::sim::SimReport::total_service_s`]
    pub total_service_s: f64,
    pub total_energy_j: f64,
    pub idle_energy_j: f64,
    pub rerouted: u64,
    pub batches: Vec<BatchStats>,
    /// serial-equivalent energy of the realized routing, accumulated in
    /// trace order — bit-identical to
    /// [`crate::sim::SimReport::serial_energy_j`]
    pub serial_energy_j: f64,
    /// Σ per-outcome energy (completion order) — the query side of the
    /// conservation check
    pub outcome_energy_j: f64,
    pub mean_latency_s: f64,
    pub mean_queue_wait_s: f64,
    /// streaming p99 latency (P² estimate; exact below five queries)
    pub p99_latency_s: f64,
    /// distinct `(m, n)` shapes seen — the [`RowCache`]'s entire
    /// footprint
    pub unique_shapes: usize,
    /// most queries resident at once (in flight on nodes + waiting in
    /// virtual queues), sampled at each arrival — the O(pending) term
    /// of the memory bound
    pub peak_pending: usize,
    /// per-tenant admission outcomes — empty when `opts.admission` is
    /// `None` (same semantics as [`crate::sim::SimReport::shed`])
    pub shed: Vec<ShedStats>,
    /// retries scheduled per system under fault injection (all zero on
    /// fault-free runs — same semantics as
    /// [`crate::sim::SimReport::retries`])
    pub retries: Vec<u64>,
    /// joules burned by crashed attempts that produced no outcome (0.0
    /// on fault-free runs — same semantics as
    /// [`crate::sim::SimReport::wasted_energy_j`])
    pub wasted_energy_j: f64,
}

impl StreamReport {
    pub fn energy_per_query(&self) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        self.total_energy_j / self.queries as f64
    }

    /// conservation check: Σ query energy (plus fault-wasted joules)
    /// == Σ system energy
    pub fn energy_conserved(&self) -> bool {
        let by_system: f64 = self.systems.iter().map(|s| s.energy_j).sum();
        (self.outcome_energy_j + self.wasted_energy_j - by_system).abs()
            <= 1e-6 * by_system.max(1.0)
    }

    /// queries routed to each system, in system order
    pub fn routing_counts(&self) -> Vec<u64> {
        self.systems.iter().map(|s| s.queries).collect()
    }

    /// total batches dispatched across systems
    pub fn total_dispatches(&self) -> u64 {
        self.batches.iter().map(|b| b.dispatches).sum()
    }

    /// total queries shed across tenants (0 when admission is disabled)
    pub fn total_shed(&self) -> u64 {
        self.shed.iter().map(ShedStats::shed_total).sum()
    }

    /// shed fraction over all arrivals (served + shed)
    pub fn shed_rate(&self) -> f64 {
        let arrived: u64 = self.shed.iter().map(|s| s.arrived).sum();
        if arrived == 0 {
            0.0
        } else {
            self.total_shed() as f64 / arrived as f64
        }
    }

    /// total queries abandoned after exhausting their retry budget
    /// (0 when faults are disabled)
    pub fn total_abandoned(&self) -> u64 {
        self.shed.iter().map(|s| s.abandoned).sum()
    }

    /// total retries scheduled across systems (0 when faults are
    /// disabled)
    pub fn total_retries(&self) -> u64 {
        self.retries.iter().sum()
    }

    /// served / arrived over all tenants (1.0 when the shed ledger is
    /// empty — fault-free, admission-free runs complete everything)
    pub fn completion_rate(&self) -> f64 {
        let arrived: u64 = self.shed.iter().map(|s| s.arrived).sum();
        if arrived == 0 {
            return 1.0;
        }
        let served: u64 = self.shed.iter().map(|s| s.served).sum();
        served as f64 / arrived as f64
    }
}

/// Run a streaming simulation, pulling at most `limit` queries from the
/// source (fewer if it ends first). Serial when `opts.batching` is
/// `None`, batched otherwise — the same mode split as
/// [`crate::sim::engine::simulate`]. Arrivals must be non-decreasing;
/// a misordered source is an `Err` (streams are user data — a CSV —
/// where the materialized engines' assert would be a panic on input).
pub fn simulate_stream(
    source: &mut dyn QuerySource,
    limit: usize,
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    energy: &EnergyModel,
    opts: &SimOptions,
) -> Result<StreamReport, String> {
    simulate_stream_with_sink(source, limit, systems, policy, energy, opts, &mut |_, _| {})
}

/// [`simulate_stream`] with a per-outcome callback: `sink(seq, outcome)`
/// fires once per query, in completion order, with `seq` the query's
/// 0-based trace sequence number. This is how equivalence tests compare
/// streaming outcomes field-for-field against materialized runs without
/// the streaming path ever retaining them.
pub fn simulate_stream_with_sink(
    source: &mut dyn QuerySource,
    limit: usize,
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    energy: &EnergyModel,
    opts: &SimOptions,
    sink: &mut dyn FnMut(u64, &QueryOutcome),
) -> Result<StreamReport, String> {
    let mut cache = RowCache::new(energy.clone(), systems);
    if faults_live(opts) {
        // live fault injection diverts every configuration to the
        // fault-aware loop — the streaming mirror of
        // `engine::simulate_faulted` (fault-free runs never reach it,
        // keeping them bit-identical to the historical engines)
        let batch_table = opts
            .batching
            .map(|b| BatchTable::new(energy.clone(), systems).with_capacity(b.memo_capacity));
        return stream_faulted(
            source,
            limit,
            systems,
            policy,
            &mut cache,
            batch_table.as_ref(),
            opts,
            sink,
        );
    }
    match opts.batching {
        None => stream_serial(source, limit, systems, policy, &mut cache, opts, sink),
        Some(bopts) => {
            let batch_table =
                BatchTable::new(energy.clone(), systems).with_capacity(bopts.memo_capacity);
            StreamSim::new(systems, batch_table, opts, bopts)
                .run(source, limit, policy, &mut cache, sink)
        }
    }
}

fn check_sorted(q: &Query, last_arrival: f64, seq: u64) -> Result<(), String> {
    if q.arrival_s < last_arrival {
        return Err(format!(
            "stream not sorted by arrival: query #{seq} (id {}) arrives at {} after {}",
            q.id, q.arrival_s, last_arrival
        ));
    }
    Ok(())
}

/// The running state both streaming modes share: cluster, outcome
/// accumulators, batch stats, and the reroute counter.
struct StreamTotals {
    cluster: ClusterState,
    acc: StreamingOutcomes,
    batches: Vec<BatchStats>,
    rerouted: u64,
    peak_pending: usize,
    /// shared admission policy, live iff `opts.admission` is `Some`
    overload: Option<OverloadPolicy>,
    ledger: ShedLedger,
}

impl StreamTotals {
    fn new(systems: &[SystemSpec], opts: &SimOptions) -> Self {
        Self {
            cluster: ClusterState::new(systems),
            acc: StreamingOutcomes::new(),
            batches: vec![BatchStats::default(); systems.len()],
            rerouted: 0,
            peak_pending: 0,
            overload: opts.admission.clone().map(OverloadPolicy::new),
            ledger: ShedLedger::new(),
        }
    }

    /// Policy assignment + feasibility fallback — the streaming mirror
    /// of `engine::route_query`, against [`RowCache`] rows instead of
    /// table rows (same checks, same panic messages, same fallback
    /// tie-break via [`RowCache::cheapest_feasible`]).
    fn route(
        &mut self,
        policy: &mut dyn Policy,
        q: &Query,
        row: usize,
        view: &ClusterView,
        cache: &RowCache,
        strict: bool,
    ) -> SystemId {
        let (m, n) = (q.input_tokens, q.output_tokens);
        let mut sid = policy.assign(q, view);
        assert!(sid.0 < self.cluster.nodes.len(), "policy returned out-of-range system");
        if !cache.is_feasible(row, sid.0) {
            if strict {
                panic!(
                    "policy '{}' routed infeasible query (m={m}, n={n}) to {}",
                    policy.name(),
                    self.cluster.nodes[sid.0].spec.name
                );
            }
            sid = SystemId(
                cache
                    .cheapest_feasible(row)
                    .unwrap_or_else(|| panic!("query (m={m},n={n}) feasible nowhere")),
            );
            self.rerouted += 1;
        }
        sid
    }

    /// Reject-on-arrival for a routed query — the streaming mirror of
    /// the materialized engines' admission block (same decision inputs,
    /// same feasibility-guarded SLO upgrade), strictly after
    /// [`StreamTotals::route`] so shed queries still advance policy
    /// state. On shed the sequence number is [`StreamingOutcomes::skip`]ped
    /// so the reorder cursor steps over it, and `None` comes back.
    fn admit(
        &mut self,
        q: &Query,
        seq: u64,
        row: usize,
        mut sid: SystemId,
        depths: &[f64],
        lens: &[usize],
        cache: &RowCache,
    ) -> Option<SystemId> {
        let Some(ov) = self.overload.as_mut() else { return Some(sid) };
        self.ledger.arrive(q.tenant);
        let mut eta = |s: usize| {
            if cache.is_feasible(row, s) {
                depths[s] + cache.runtime_s(row, s)
            } else {
                f64::INFINITY
            }
        };
        match ov.decide(q, q.arrival_s, sid.0, lens, &mut eta) {
            AdmitDecision::Admit(s2) => {
                // never upgrade onto an infeasible system (only
                // reachable for deadline-free queries when every
                // eligible system is infeasible)
                if s2 != sid.0 && cache.is_feasible(row, s2) {
                    self.ledger.upgrade(q.tenant);
                    sid = SystemId(s2);
                }
                self.ledger.serve(q.tenant);
                Some(sid)
            }
            AdmitDecision::Shed(reason) => {
                self.ledger.shed(q.tenant, reason);
                self.acc.skip(seq);
                None
            }
        }
    }

    /// Makespan/idle accounting + report assembly — the streaming
    /// mirror of `engine::finalize_report`, with the outcome-derived
    /// numbers read off the accumulators.
    fn finish(self, policy_name: String, opts: &SimOptions, unique_shapes: usize) -> StreamReport {
        let makespan = self.cluster.makespan();
        let idle_energy: f64 = if opts.include_idle_energy {
            self.cluster
                .nodes
                .iter()
                .map(|node| {
                    let spec = &node.spec;
                    let capacity_s = makespan * spec.count as f64;
                    debug_assert!(
                        node.busy_s <= capacity_s + 1e-9 * capacity_s.max(1.0),
                        "{}: busy_s {} exceeds makespan × count = {} — scheduling accounting bug",
                        spec.name,
                        node.busy_s,
                        capacity_s
                    );
                    spec.idle_w * (capacity_s - node.busy_s).max(0.0)
                })
                .sum()
        } else {
            0.0
        };
        let total_energy: f64 =
            self.cluster.nodes.iter().map(|n| n.energy_j).sum::<f64>() + idle_energy;
        let n_systems = self.batches.len();

        StreamReport {
            policy: policy_name,
            queries: self.acc.count(),
            systems: self
                .cluster
                .nodes
                .iter()
                .map(|n| SystemTotals {
                    name: n.spec.name.to_string(),
                    queries: n.queries,
                    busy_s: n.busy_s,
                    energy_j: n.energy_j,
                })
                .collect(),
            makespan_s: makespan,
            total_service_s: self.acc.total_service_s(),
            total_energy_j: total_energy,
            idle_energy_j: idle_energy,
            rerouted: self.rerouted,
            batches: self.batches,
            serial_energy_j: self.acc.serial_energy_j(),
            outcome_energy_j: self.acc.outcome_energy_j(),
            mean_latency_s: self.acc.mean_latency_s(),
            mean_queue_wait_s: self.acc.mean_queue_wait_s(),
            p99_latency_s: self.acc.p99_latency_s(),
            unique_shapes,
            peak_pending: self.peak_pending,
            retries: vec![0; n_systems],
            wasted_energy_j: 0.0,
            shed: self.ledger.into_stats(),
        }
    }
}

/// Serial streaming loop — the [`crate::sim::simulate_with_table`] loop
/// over a source, with [`RowCache`] rows in place of table rows. Every
/// expression mirrors the materialized loop, so outcomes are
/// bit-identical on the same trace.
fn stream_serial(
    source: &mut dyn QuerySource,
    limit: usize,
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    cache: &mut RowCache,
    opts: &SimOptions,
    sink: &mut dyn FnMut(u64, &QueryOutcome),
) -> Result<StreamReport, String> {
    let mut st = StreamTotals::new(systems, opts);
    let mut last_arrival = f64::NEG_INFINITY;
    let mut seq = 0u64;
    while (seq as usize) < limit {
        let Some(q) = source.next_query()? else { break };
        check_sorted(&q, last_arrival, seq)?;
        last_arrival = q.arrival_s;
        let row = cache.row(q.input_tokens, q.output_tokens);
        st.cluster.advance_to(q.arrival_s);
        let depths = st.cluster.queue_depths_at(q.arrival_s);
        let lens = st.cluster.queue_lens();
        st.peak_pending = st.peak_pending.max(lens.iter().sum::<usize>() + 1);
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let sid = st.route(policy, &q, row, &view, cache, opts.strict);
        let Some(sid) = st.admit(&q, seq, row, sid, &depths, &lens, cache) else {
            seq += 1;
            continue;
        };

        let service = cache.runtime_s(row, sid.0);
        let e_j = cache.energy_j(row, sid.0);
        let node = st.cluster.get_mut(sid);
        let (start, finish) = node.schedule(q.arrival_s, service);
        node.energy_j += e_j;
        st.batches[sid.0].record(1, systems[sid.0].dispatch_energy_j(), 0);
        let o = QueryOutcome {
            query_id: q.id,
            system: sid.0,
            arrival_s: q.arrival_s,
            start_s: start,
            finish_s: finish,
            service_s: service,
            energy_j: e_j,
        };
        st.acc.push(seq, &o, e_j);
        sink(seq, &o);
        seq += 1;
    }
    Ok(st.finish(policy.name(), opts, cache.n_unique_rows()))
}

/// One unit of dispatchable work in the streaming fault loop — the
/// streaming twin of the engine's `FaultJob`, keyed by trace sequence
/// number and carrying its [`RowCache`] row so retries re-price without
/// re-reading the source.
#[derive(Clone, Copy, Debug)]
struct StreamFaultJob {
    seq: u64,
    id: u64,
    arrival_s: f64,
    /// when this job entered its current queue (original arrival for
    /// first attempts, backoff expiry for retries)
    enq_s: f64,
    m: u32,
    n: u32,
    row: usize,
    tenant: u32,
}

/// The fault-aware streaming loop — `engine::simulate_faulted` over a
/// [`QuerySource`], expression-for-expression: one FIFO queue per
/// system class, FIFO-prefix batches trimmed through the same
/// [`BatchTable`], dispatch on the node with the earliest
/// fault-adjusted availability, crashes booking partial work and
/// requeuing members through the shared retry/backoff policy, retries
/// optionally moving to the minimum-ETA feasible system. Because every
/// routing, pricing, scheduling, and attribution step mirrors the
/// materialized loop (with [`RowCache`] rows in place of table rows), a
/// streaming fault run over [`crate::workload::source::SliceSource`] is
/// bit-identical to the materialized fault run on the same trace —
/// pinned in `rust/tests/fault_properties.rs`. Outcomes flow through
/// [`StreamingOutcomes`] out of completion order (served retries land
/// late; the reorder buffer restores trace-order sums), and abandoned
/// sequence numbers are [`StreamingOutcomes::skip`]ped exactly like
/// shed ones.
#[allow(clippy::too_many_arguments)]
fn stream_faulted(
    source: &mut dyn QuerySource,
    limit: usize,
    systems: &[SystemSpec],
    policy: &mut dyn Policy,
    cache: &mut RowCache,
    batch_table: Option<&BatchTable>,
    opts: &SimOptions,
    sink: &mut dyn FnMut(u64, &QueryOutcome),
) -> Result<StreamReport, String> {
    let fcfg = opts.faults.as_ref().expect("stream_faulted requires SimOptions::faults");
    debug_assert!(fcfg.enabled(), "disabled fault configs take the fault-free loops");
    if let Err(e) = fcfg.validate() {
        return Err(format!("invalid fault config: {e}"));
    }
    let (max_batch, linger_s) = match (&opts.batching, batch_table) {
        (Some(b), Some(bt)) => {
            assert!(b.max_batch >= 1, "max_batch must be >= 1");
            assert!(
                b.linger_s >= 0.0 && b.linger_s.is_finite(),
                "linger_s must be finite and non-negative"
            );
            assert_eq!(bt.n_systems(), systems.len(), "batch table must match the cluster");
            (b.max_batch, b.linger_s)
        }
        (None, None) => (1, 0.0),
        _ => panic!("batching options and batch table must be supplied together"),
    };

    let mut fs = FaultState::new(fcfg, systems.len());
    let mut st = StreamTotals::new(systems, opts);
    let mut queues: Vec<VecDeque<StreamFaultJob>> =
        (0..systems.len()).map(|_| VecDeque::new()).collect();
    let mut upcoming: Option<(u64, Query)> = None;
    let mut pulled = 0usize;
    let mut last_arrival = f64::NEG_INFINITY;
    let mut popped: Vec<StreamFaultJob> = Vec::new();
    let mut pairs: Vec<(u32, u32)> = Vec::new();
    let mut member_rel: Vec<f64> = Vec::new();

    loop {
        // keep exactly one arrival buffered
        if upcoming.is_none() && pulled < limit {
            match source.next_query()? {
                Some(q) => {
                    let seq = pulled as u64;
                    check_sorted(&q, last_arrival, seq)?;
                    last_arrival = q.arrival_s;
                    upcoming = Some((seq, q));
                    pulled += 1;
                }
                None => pulled = limit,
            }
        }
        let next_arrival = upcoming.as_ref().map_or(f64::INFINITY, |(_, q)| q.arrival_s);
        let next_retry = fs.next_due().unwrap_or(f64::INFINITY);
        let next_in = next_arrival.min(next_retry);

        // earliest due batch across the class queues (strict `<`, so
        // ties break to the lowest system index) — same expressions as
        // the materialized fault loop
        let mut due: Option<(f64, usize)> = None;
        for (s, q) in queues.iter().enumerate() {
            let Some(front) = q.front() else { continue };
            let free = st.cluster.nodes[s].earliest_free();
            let ready = if q.len() >= max_batch {
                free.max(q[max_batch - 1].enq_s)
            } else {
                free.max(front.enq_s) + linger_s
            };
            if due.map_or(true, |(t, _)| ready < t) {
                due = Some((ready, s));
            }
        }

        if let Some((ready, s)) = due {
            if ready <= next_in {
                popped.clear();
                let cap = max_batch.min(queues[s].len());
                popped.extend(queues[s].drain(..cap));
                pairs.clear();
                pairs.extend(popped.iter().map(|j| (j.m, j.n)));
                let take = match batch_table {
                    Some(bt) => bt.feasible_prefix(s, &pairs),
                    None => 1,
                };
                assert!(take >= 1, "batch head must be individually feasible on its system");
                for j in popped.drain(take..).rev() {
                    queues[s].push_front(j);
                }
                pairs.truncate(take);

                member_rel.clear();
                let (base_dur, e_base) = match batch_table {
                    Some(bt) => {
                        let cost = bt.cost(s, &pairs);
                        debug_assert!(cost.is_feasible(), "trimmed batch must be feasible");
                        member_rel.extend_from_slice(&cost.member_finish_s);
                        (cost.runtime_s, bt.energy_j(&cost))
                    }
                    None => {
                        let row = popped[0].row;
                        let dur = cache.runtime_s(row, s);
                        member_rel.push(dur);
                        (dur, cache.energy_j(row, s))
                    }
                };

                let mut node_idx = 0usize;
                let mut best_start = f64::INFINITY;
                for (w, &free_w) in st.cluster.nodes[s].node_free_at.iter().enumerate() {
                    let est = fs.plan.up_at(s, w, ready.max(free_w));
                    if est < best_start {
                        best_start = est;
                        node_idx = w;
                    }
                }
                let free_n = st.cluster.nodes[s].node_free_at[node_idx];
                let att = fs.plan.attempt_span(s, node_idx, ready.max(free_n), base_dur);
                debug_assert_eq!(att.start_s.to_bits(), best_start.to_bits());
                let e_scaled = e_base * att.factor;

                if let Some(c) = att.crash_s {
                    let e_partial = e_scaled * att.executed_fraction();
                    fs.wasted_energy_j += e_partial;
                    let resume = fs.plan.up_at(s, node_idx, c);
                    st.cluster.nodes[s].book_crash_on(node_idx, att.start_s, c, resume, e_partial);
                    for j in &popped {
                        let a = RetryAttempt {
                            due_s: 0.0,
                            orig: j.seq,
                            system: s,
                            id: j.id,
                            arrival_s: j.arrival_s,
                            m: j.m,
                            n: j.n,
                            row: j.row,
                            tenant: j.tenant,
                        };
                        if fs.fail(a, c).is_none() {
                            st.ledger.abandon(j.tenant);
                            st.acc.skip(j.seq);
                        }
                    }
                } else {
                    for f in member_rel.iter_mut() {
                        *f *= att.factor;
                    }
                    let start = st.cluster.nodes[s].schedule_batch_on(
                        node_idx,
                        att.start_s,
                        att.dur_s,
                        &member_rel,
                    );
                    debug_assert_eq!(start.to_bits(), att.start_s.to_bits());
                    st.cluster.nodes[s].energy_j += e_scaled;
                    st.batches[s].record(
                        take,
                        systems[s].dispatch_energy_j(),
                        FormationPolicy::straggler_steps(&pairs),
                    );
                    let batch_tokens: f64 = pairs.iter().map(|&(m, n)| (m + n) as f64).sum();
                    for (k, j) in popped.iter().enumerate() {
                        let share = (pairs[k].0 + pairs[k].1) as f64 / batch_tokens;
                        let o = QueryOutcome {
                            query_id: j.id,
                            system: s,
                            arrival_s: j.arrival_s,
                            start_s: start,
                            finish_s: start + member_rel[k],
                            service_s: member_rel[k],
                            energy_j: e_scaled * share,
                        };
                        st.acc.push(j.seq, &o, cache.energy_j(j.row, s));
                        sink(j.seq, &o);
                        st.ledger.serve(j.tenant);
                        fs.served(j.seq);
                    }
                }
                continue;
            }
        }

        if next_in == f64::INFINITY {
            break;
        }

        if next_arrival <= next_retry {
            // route the next arrival (arrivals win ties over backoffs,
            // matching the materialized loop)
            let (seq, q) = upcoming.take().expect("next_arrival was finite");
            let row = cache.row(q.input_tokens, q.output_tokens);
            st.cluster.advance_to(q.arrival_s);
            let mut depths = st.cluster.queue_depths_at(q.arrival_s);
            let mut lens = st.cluster.queue_lens();
            for (s, pq) in queues.iter().enumerate() {
                if pq.is_empty() {
                    continue;
                }
                lens[s] += pq.len();
                depths[s] += pq.iter().map(|j| cache.runtime_s(j.row, s)).sum::<f64>();
            }
            st.peak_pending = st.peak_pending.max(lens.iter().sum::<usize>() + 1);
            let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
            let mut sid = st.route(policy, &q, row, &view, cache, opts.strict);
            // fault mode always runs the ledger, admission or not:
            // abandonment makes conservation non-vacuous even for
            // admit-everything configs. Serve is recorded at outcome
            // emission (a query in the retry loop is neither).
            st.ledger.arrive(q.tenant);
            if let Some(ov) = st.overload.as_mut() {
                let mut eta = |s: usize| {
                    if cache.is_feasible(row, s) {
                        depths[s] + cache.runtime_s(row, s)
                    } else {
                        f64::INFINITY
                    }
                };
                match ov.decide(&q, q.arrival_s, sid.0, &lens, &mut eta) {
                    AdmitDecision::Admit(s2) => {
                        if s2 != sid.0 && cache.is_feasible(row, s2) {
                            st.ledger.upgrade(q.tenant);
                            sid = SystemId(s2);
                        }
                    }
                    AdmitDecision::Shed(reason) => {
                        st.ledger.shed(q.tenant, reason);
                        st.acc.skip(seq);
                        continue;
                    }
                }
            }
            queues[sid.0].push_back(StreamFaultJob {
                seq,
                id: q.id,
                arrival_s: q.arrival_s,
                enq_s: q.arrival_s,
                m: q.input_tokens,
                n: q.output_tokens,
                row,
                tenant: q.tenant,
            });
        } else {
            // a retry's backoff expired: requeue on the failed system
            // or — when the policy allows — on the minimum-ETA feasible
            // system (same scan as the materialized loop; retries
            // bypass admission and the routing policy)
            let a = fs.pop_due().expect("next_retry was finite");
            st.cluster.advance_to(a.due_s);
            let target = if fs.retry.retry_other_system {
                let depths = st.cluster.queue_depths_at(a.due_s);
                let mut best = a.system;
                let mut best_eta = f64::INFINITY;
                for (s, d) in depths.iter().enumerate() {
                    if !cache.is_feasible(a.row, s) {
                        continue;
                    }
                    let backlog: f64 =
                        queues[s].iter().map(|j| cache.runtime_s(j.row, s)).sum();
                    let eta = d + backlog + cache.runtime_s(a.row, s);
                    if eta < best_eta {
                        best_eta = eta;
                        best = s;
                    }
                }
                best
            } else {
                a.system
            };
            queues[target].push_back(StreamFaultJob {
                seq: a.orig,
                id: a.id,
                arrival_s: a.arrival_s,
                enq_s: a.due_s,
                m: a.m,
                n: a.n,
                row: a.row,
                tenant: a.tenant,
            });
        }
    }

    debug_assert_eq!(fs.abandoned, st.ledger.total_abandoned(), "abandonment double-entry");
    let unique_shapes = cache.n_unique_rows();
    let mut report = st.finish(policy.name(), opts, unique_shapes);
    report.retries = fs.retries_by_system;
    report.wasted_energy_j = fs.wasted_energy_j;
    Ok(report)
}

/// One resident waiter of a streaming virtual queue: everything the
/// batched loop ever reads about a query after routing — so the `Query`
/// itself (and its cost row) can be dropped the moment its outcome is
/// attributed.
#[derive(Clone, Copy, Debug)]
struct PendingQuery {
    /// 0-based trace sequence number (the reorder key and window id)
    seq: u64,
    id: u64,
    arrival_s: f64,
    m: u32,
    n: u32,
    /// this shape's [`RowCache`] row
    row: usize,
}

/// Streaming sibling of the materialized engine's `WorkerQueue`: the
/// pending deque owns [`PendingQuery`] values (there is no trace to
/// index into), plus the same reusable window/selection/scratch buffers
/// — and a `members` buffer holding the dispatching batch's waiters,
/// since they leave the queue before their outcomes are attributed.
struct StreamWorkerQueue {
    /// waiting queries in arrival order (ascending `seq`)
    pending: VecDeque<PendingQuery>,
    window: SortedWindow,
    /// selected seqs, ascending ([`SortedWindow`] keys)
    sel: Vec<u64>,
    /// `(m, n)` of the selection, in `sel` order
    pairs: Vec<(u32, u32)>,
    /// the selected waiters, in `sel` order
    members: Vec<PendingQuery>,
    scratch: FormationScratch,
}

impl StreamWorkerQueue {
    fn new() -> Self {
        Self {
            pending: VecDeque::new(),
            window: SortedWindow::new(),
            sel: Vec::new(),
            pairs: Vec::new(),
            members: Vec::new(),
            scratch: FormationScratch::default(),
        }
    }
}

/// Batched streaming engine: the event-heap dispatch loop of
/// `engine::simulate_batched_with_tables` over a source. Same due-time
/// expressions, same formation/trim/removal order, same scheduling and
/// attribution arithmetic — the only structural differences are that
/// queues own their waiters' data and outcomes flow through the
/// accumulator/sink instead of a vector.
struct StreamSim<'a> {
    systems: &'a [SystemSpec],
    batch_table: BatchTable,
    opts: &'a SimOptions,
    bopts: BatchingOptions,
    /// lookahead width when the formation policy looks past one batch;
    /// 0 = window-less (FIFO semantics, eager dispatch instants)
    window_cap: usize,
    /// full-batch membership decided at hand-off (`window_cap > 0`)
    hand_off_gated: bool,
    queues: Vec<Vec<StreamWorkerQueue>>,
    totals: StreamTotals,
    /// `Some(cap)` iff iteration-level admission is live — same
    /// derivation as `BatchedSim::live_cap`
    live_cap: Option<usize>,
    /// `episodes[s][node]`: the in-flight continuous episode there
    episodes: Vec<Vec<Option<Episode>>>,
    /// members resident in episodes, keyed by trace sequence number —
    /// everything needed to attribute their outcomes at retirement
    /// (episodes index members by `seq`, the streaming stand-in for the
    /// materialized engine's trace index)
    ep_resident: HashMap<u64, PendingQuery>,
    /// scratch buffers mirroring `BatchedSim`'s
    ep_pairs: Vec<(u32, u64)>,
    ep_live_mn: Vec<(u32, u32)>,
    ep_cand: Vec<(u32, u32)>,
    ep_admit: Vec<(u32, u32)>,
    ep_finish: Vec<f64>,
    ep_new_finish: Vec<f64>,
}

impl<'a> StreamSim<'a> {
    fn new(
        systems: &'a [SystemSpec],
        batch_table: BatchTable,
        opts: &'a SimOptions,
        bopts: BatchingOptions,
    ) -> Self {
        assert!(bopts.max_batch >= 1, "max_batch must be >= 1");
        assert!(
            bopts.linger_s >= 0.0 && bopts.linger_s.is_finite(),
            "linger_s must be finite and non-negative"
        );
        assert_eq!(batch_table.n_systems(), systems.len(), "batch table must match the cluster");
        // same hand-off gating rule as the materialized engine — see
        // `BatchedSim::new` for the full rationale
        let window_cap = {
            let cap = bopts.formation.candidate_window(bopts.max_batch);
            if bopts.max_batch > 1 && cap > bopts.max_batch {
                cap
            } else {
                0
            }
        };
        // same derivation as `BatchedSim::new`: every degenerate
        // configuration takes the static code path wholesale
        let live_cap = match bopts.mode {
            BatchMode::Continuous { max_live } if !bopts.freeze_admission && bopts.max_batch > 1 => {
                Some(if max_live == 0 { bopts.max_batch } else { max_live })
            }
            _ => None,
        };
        let episodes = if live_cap.is_some() {
            systems.iter().map(|spec| (0..spec.count.max(1)).map(|_| None).collect()).collect()
        } else {
            Vec::new()
        };
        Self {
            systems,
            batch_table,
            opts,
            bopts,
            window_cap,
            hand_off_gated: window_cap > 0,
            queues: systems
                .iter()
                .map(|spec| {
                    let n = match bopts.queues {
                        QueueModel::PerWorker => spec.count.max(1),
                        QueueModel::PerClass => 1,
                    };
                    (0..n).map(|_| StreamWorkerQueue::new()).collect()
                })
                .collect(),
            totals: StreamTotals::new(systems, opts),
            live_cap,
            episodes,
            ep_resident: HashMap::new(),
            ep_pairs: Vec::new(),
            ep_live_mn: Vec::new(),
            ep_cand: Vec::new(),
            ep_admit: Vec::new(),
            ep_finish: Vec::new(),
            ep_new_finish: Vec::new(),
        }
    }

    /// The instant queue `(s, w)` next needs service — identical
    /// expressions to `BatchedSim::queue_ready`: the earlier of the
    /// founding instant and (in continuous mode) the next step boundary
    /// of an episode this queue feeds.
    fn queue_ready(&self, s: usize, w: usize) -> f64 {
        let founding = self.founding_ready(s, w);
        match self.earliest_boundary(s, w) {
            Some((b, _)) if b <= founding => b,
            _ => founding,
        }
    }

    /// Streaming twin of `BatchedSim::earliest_boundary`.
    fn earliest_boundary(&self, s: usize, w: usize) -> Option<(f64, usize)> {
        self.live_cap?;
        match self.bopts.queues {
            QueueModel::PerWorker => {
                self.episodes[s][w].as_ref().map(|ep| (ep.next_boundary_s, w))
            }
            QueueModel::PerClass => {
                let mut best: Option<(f64, usize)> = None;
                for (node, slot) in self.episodes[s].iter().enumerate() {
                    if let Some(ep) = slot {
                        if best.map_or(true, |(t, _)| ep.next_boundary_s < t) {
                            best = Some((ep.next_boundary_s, node));
                        }
                    }
                }
                best
            }
        }
    }

    /// Streaming twin of `BatchedSim::founding_ready`, with arrivals
    /// read off the owned waiters instead of the trace.
    fn founding_ready(&self, s: usize, w: usize) -> f64 {
        let wq = &self.queues[s][w];
        let front = wq.pending.front().expect("queue_ready needs a non-empty queue");
        let free = match self.bopts.queues {
            QueueModel::PerWorker => self.totals.cluster.nodes[s].node_free_at[w],
            QueueModel::PerClass => self.totals.cluster.nodes[s].earliest_free(),
        };
        if wq.pending.len() >= self.bopts.max_batch {
            let filling = wq.pending[self.bopts.max_batch - 1].arrival_s;
            if self.hand_off_gated || self.live_cap.is_some() {
                free.max(filling)
            } else {
                filling
            }
        } else {
            free.max(front.arrival_s) + self.bopts.linger_s
        }
    }

    /// Re-derive queue `(s, w)`'s due event after its inputs changed —
    /// the streaming twin of `engine::refresh_due_event`, sharing
    /// [`DueEvent`]'s ordering.
    fn refresh(
        &self,
        stamps: &mut [Vec<u64>],
        heap: &mut BinaryHeap<Reverse<DueEvent>>,
        s: usize,
        w: usize,
    ) {
        let stamp = &mut stamps[s][w];
        *stamp += 1;
        if self.queues[s][w].pending.is_empty() {
            return;
        }
        heap.push(Reverse(DueEvent {
            ready: self.queue_ready(s, w),
            s: s as u32,
            w: w as u32,
            stamp: *stamp,
        }));
    }

    /// Service queue `(s, w)` at its due instant `ready` —
    /// `BatchedSim::dispatch` step-for-step: advance the due step
    /// boundary in continuous mode (boundaries win ties), otherwise
    /// found a batch.
    fn dispatch(
        &mut self,
        ready: f64,
        s: usize,
        w: usize,
        cache: &RowCache,
        sink: &mut dyn FnMut(u64, &QueryOutcome),
    ) {
        if self.live_cap.is_some() {
            if let Some((b, node)) = self.earliest_boundary(s, w) {
                if b <= self.founding_ready(s, w) {
                    debug_assert_eq!(
                        b.to_bits(),
                        ready.to_bits(),
                        "a boundary-due queue must be serviced at that boundary"
                    );
                    self.advance_boundary(s, w, node, cache, sink);
                    return;
                }
            }
        }
        self.found_batch(ready, s, w, cache, sink);
    }

    /// Found queue `(s, w)`'s due batch at instant `ready` —
    /// `BatchedSim::found_batch` step-for-step, with member data copied
    /// into the queue's `members` buffer before removal so outcomes can
    /// be attributed after the waiters leave.
    fn found_batch(
        &mut self,
        ready: f64,
        s: usize,
        w: usize,
        cache: &RowCache,
        sink: &mut dyn FnMut(u64, &QueryOutcome),
    ) {
        let Self {
            systems,
            batch_table,
            bopts,
            window_cap,
            hand_off_gated,
            queues,
            totals,
            live_cap,
            episodes,
            ep_resident,
            ep_pairs,
            ..
        } = self;
        let (bopts, window_cap, hand_off_gated) = (*bopts, *window_cap, *hand_off_gated);
        let live_cap = *live_cap;
        let wq = &mut queues[s][w];
        let found_cap = live_cap.map_or(bopts.max_batch, |c| bopts.max_batch.min(c));
        if hand_off_gated {
            let front = wq.pending.front().expect("due queue has a front waiter");
            let oldest = (front.n, front.seq);
            wq.window.select_drag_minimal_with_cost(
                oldest,
                found_cap,
                bopts.dispatch_cost_steps,
                &mut wq.scratch,
                &mut wq.sel,
            );
            wq.members.clear();
            for &sq in wq.sel.iter() {
                let pos = wq
                    .pending
                    .binary_search_by_key(&sq, |p| p.seq)
                    .expect("selected member must be pending");
                wq.members.push(wq.pending[pos]);
            }
        } else {
            wq.members.clear();
            wq.members.extend(wq.pending.iter().take(found_cap).copied());
        }
        wq.pairs.clear();
        wq.pairs.extend(wq.members.iter().map(|p| (p.m, p.n)));
        // joint-KV feasibility: trim to the longest feasible prefix of
        // the selection; the tail stays queued
        let take = batch_table.feasible_prefix(s, &wq.pairs);
        wq.members.truncate(take);
        wq.pairs.truncate(take);
        if hand_off_gated {
            // descending removal keeps earlier positions stable
            for k in (0..take).rev() {
                let p = wq.members[k];
                let pos = wq
                    .pending
                    .binary_search_by_key(&p.seq, |x| x.seq)
                    .expect("selected member must be pending");
                wq.pending.remove(pos);
                wq.window.remove((p.n, p.seq));
            }
            // slide the window forward over the next-oldest waiters
            // this dispatch exposed
            while wq.window.len() < window_cap.min(wq.pending.len()) {
                let p = wq.pending[wq.window.len()];
                wq.window.insert((p.n, p.seq));
            }
        } else {
            for _ in 0..take {
                wq.pending.pop_front();
            }
        }
        let cost = batch_table.cost(s, &wq.pairs);
        debug_assert!(cost.is_feasible(), "trimmed batch must be feasible");
        let e_batch = batch_table.energy_j(&cost);
        let node = totals.cluster.get_mut(SystemId(s));
        let (start, node_idx) = match bopts.queues {
            QueueModel::PerWorker => {
                (node.schedule_batch_on(w, ready, cost.runtime_s, &cost.member_finish_s), w)
            }
            QueueModel::PerClass if live_cap.is_some() => {
                // resolve `schedule_batch`'s earliest-free pick (ties to
                // the lowest index) explicitly — identical arithmetic,
                // but continuous mode needs the hosting node's index
                let idx = node
                    .node_free_at
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .expect("system has at least one node");
                (node.schedule_batch_on(idx, ready, cost.runtime_s, &cost.member_finish_s), idx)
            }
            QueueModel::PerClass => {
                (node.schedule_batch(ready, cost.runtime_s, &cost.member_finish_s), 0)
            }
        };
        node.energy_j += e_batch;
        totals.batches[s].record(
            take,
            systems[s].dispatch_energy_j(),
            if live_cap.is_some() { 0 } else { FormationPolicy::straggler_steps(&wq.pairs) },
        );
        if live_cap.is_some() {
            // continuous: found an episode; outcomes are attributed at
            // retirement, so park the member data in `ep_resident`
            debug_assert!(
                episodes[s][node_idx].is_none(),
                "a founding lands only on an episode-free node"
            );
            let members: Vec<(usize, u32, u32)> = wq
                .members
                .iter()
                .map(|p| {
                    ep_resident.insert(p.seq, *p);
                    (p.seq as usize, p.m, p.n)
                })
                .collect();
            let mut ep = Episode::found(node_idx, start, &members, Arc::clone(&cost), e_batch);
            ep.refresh_next_boundary(&batch_table.energy_model().perf, &systems[s], ep_pairs);
            episodes[s][node_idx] = Some(ep);
            return;
        }
        let batch_tokens: f64 = wq.pairs.iter().map(|&(m, n)| (m + n) as f64).sum();
        for (k, p) in wq.members.iter().enumerate() {
            // attribute batch energy by token share (a singleton gets
            // exactly the full batch energy)
            let share = (wq.pairs[k].0 + wq.pairs[k].1) as f64 / batch_tokens;
            let o = QueryOutcome {
                query_id: p.id,
                system: s,
                arrival_s: p.arrival_s,
                start_s: start,
                finish_s: start + cost.member_finish_s[k],
                service_s: cost.member_finish_s[k],
                energy_j: e_batch * share,
            };
            totals.acc.push(p.seq, &o, cache.energy_j(p.row, s));
            sink(p.seq, &o);
        }
    }

    /// Streaming twin of `BatchedSim::advance_boundary`: retire members
    /// whose `n` is spent, admit the longest feasible FIFO prefix of the
    /// queue's waiters, re-book the node by the projection delta, and
    /// finalize the episode when its last member retires.
    fn advance_boundary(
        &mut self,
        s: usize,
        w: usize,
        node: usize,
        cache: &RowCache,
        sink: &mut dyn FnMut(u64, &QueryOutcome),
    ) {
        let Self {
            systems,
            batch_table,
            bopts,
            window_cap,
            hand_off_gated,
            queues,
            totals,
            live_cap,
            episodes,
            ep_resident,
            ep_pairs,
            ep_live_mn,
            ep_cand,
            ep_admit,
            ep_finish,
            ep_new_finish,
            ..
        } = self;
        let (bopts, window_cap, hand_off_gated) = (*bopts, *window_cap, *hand_off_gated);
        let live_cap = live_cap.expect("advance_boundary requires continuous mode");
        let perf = &batch_table.energy_model().perf;
        let spec = &systems[s];
        let ep = episodes[s][node].as_mut().expect("advance_boundary needs a live episode");
        let t_boundary = ep.next_boundary_s;
        let retired = ep.advance_retirement(perf, spec, ep_pairs);
        debug_assert!(retired > 0, "a boundary event must retire at least one member");

        let wq = &mut queues[s][w];
        let room = live_cap.saturating_sub(ep.live.len());
        if room > 0 && !wq.pending.is_empty() {
            ep_cand.clear();
            ep_cand.extend(wq.pending.iter().take(room).map(|p| (p.m, p.n)));
            ep_live_mn.clear();
            ep_live_mn.extend(ep.live.iter().map(|lm| (lm.m, lm.n)));
            let k = admission::admit_prefix_with(perf, spec, ep_live_mn, ep_cand, room, ep_admit);
            if k > 0 {
                ep.overhead_s += spec.overhead_s;
                for _ in 0..k {
                    let p = wq.pending.pop_front().expect("admitted member must be pending");
                    if hand_off_gated {
                        wq.window.remove((p.n, p.seq));
                    }
                    ep.prefill_s += perf.prefill_time(spec, p.m);
                    ep.admit(LiveMember {
                        qi: p.seq as usize,
                        m: p.m,
                        n: p.n,
                        joined: ep.step,
                        admit_s: t_boundary,
                    });
                    ep_resident.insert(p.seq, p);
                }
                while wq.window.len() < window_cap.min(wq.pending.len()) {
                    let p = wq.pending[wq.window.len()];
                    wq.window.insert((p.n, p.seq));
                }
                totals.batches[s].record(k, spec.dispatch_energy_j(), 0);
                let decode_total = ep.project_decode(perf, spec, ep_pairs, ep_finish);
                let runtime = ep.overhead_s + ep.prefill_s + decode_total;
                let energy = episode_energy(
                    spec,
                    ep.overhead_s,
                    ep.prefill_s,
                    decode_total,
                    batch_table.attribution(),
                );
                ep_new_finish.clear();
                for (lm, &f) in ep.live.iter().zip(ep_finish.iter()) {
                    if lm.joined == ep.step {
                        ep_new_finish.push(ep.start_s + f);
                    }
                }
                let node_state = totals.cluster.get_mut(SystemId(s));
                node_state.extend_batch_on(
                    node,
                    ep.start_s + runtime,
                    runtime - ep.booked_runtime_s,
                    ep_new_finish,
                );
                node_state.energy_j += energy - ep.booked_energy_j;
                ep.booked_runtime_s = runtime;
                ep.booked_energy_j = energy;
            }
        }

        if ep.live.is_empty() {
            let ep = episodes[s][node].take().expect("episode was live above");
            emit_stream_episode(batch_table, s, totals, ep_resident, cache, sink, ep);
        } else {
            ep.refresh_next_boundary(perf, spec, ep_pairs);
        }
    }

    /// Streaming twin of `BatchedSim::catch_up`: replay boundaries that
    /// fell at or before `t` while queue `(s, w)` sat empty.
    fn catch_up(
        &mut self,
        s: usize,
        w: usize,
        t: f64,
        cache: &RowCache,
        sink: &mut dyn FnMut(u64, &QueryOutcome),
    ) {
        loop {
            match self.earliest_boundary(s, w) {
                Some((b, node)) if b <= t => {
                    debug_assert!(self.queues[s][w].pending.is_empty());
                    self.advance_boundary(s, w, node, cache, sink)
                }
                _ => break,
            }
        }
    }

    /// Streaming twin of `BatchedSim::drain_episodes`, run once before
    /// the report is assembled.
    fn drain_episodes(&mut self, cache: &RowCache, sink: &mut dyn FnMut(u64, &QueryOutcome)) {
        if self.live_cap.is_none() {
            return;
        }
        for s in 0..self.systems.len() {
            for node in 0..self.episodes[s].len() {
                while self.episodes[s][node].is_some() {
                    let w = match self.bopts.queues {
                        QueueModel::PerWorker => node,
                        QueueModel::PerClass => 0,
                    };
                    debug_assert!(
                        self.queues[s][w].pending.is_empty(),
                        "drain only runs after every waiter was serviced"
                    );
                    self.advance_boundary(s, w, node, cache, sink);
                }
            }
        }
    }

    /// Route one arrival — `BatchedSim::route_next_arrival` over owned
    /// waiters. Returns the `(system, worker)` queue joined, or `None`
    /// when admission shed the query on arrival (it joins no queue; its
    /// sequence number is skipped in the accumulators).
    fn route_arrival(
        &mut self,
        policy: &mut dyn Policy,
        seq: u64,
        q: &Query,
        cache: &mut RowCache,
        sink: &mut dyn FnMut(u64, &QueryOutcome),
    ) -> Option<(usize, usize)> {
        let systems = self.systems;
        let strict = self.opts.strict;
        let row = cache.row(q.input_tokens, q.output_tokens);
        self.totals.cluster.advance_to(q.arrival_s);
        let mut depths = self.totals.cluster.queue_depths_at(q.arrival_s);
        let mut lens = self.totals.cluster.queue_lens();
        for (s, sys_queues) in self.queues.iter().enumerate() {
            for wq in sys_queues {
                if wq.pending.is_empty() {
                    continue;
                }
                lens[s] += wq.pending.len();
                depths[s] += wq.pending.iter().map(|p| cache.runtime_s(p.row, s)).sum::<f64>();
            }
        }
        self.totals.peak_pending =
            self.totals.peak_pending.max(lens.iter().sum::<usize>() + 1);
        let view = ClusterView { systems, queue_depth_s: &depths, queue_len: &lens };
        let sid = self.totals.route(policy, q, row, &view, cache, strict);
        let sid = self.totals.admit(q, seq, row, sid, &depths, &lens, cache)?;
        let w = pick_stream_queue(
            &self.totals.cluster.nodes[sid.0],
            &self.queues[sid.0],
            q.arrival_s,
            cache,
            sid.0,
        );
        // replay step boundaries this queue's episodes passed while it
        // sat empty — see `BatchedSim::route_next_arrival`
        if self.live_cap.is_some() {
            self.catch_up(sid.0, w, q.arrival_s, cache, sink);
        }
        let wq = &mut self.queues[sid.0][w];
        // the new waiter enters the sorted window iff it lands within
        // the lookahead cap (deeper waiters enter as dispatches expose
        // them)
        if self.hand_off_gated && wq.pending.len() < self.window_cap {
            wq.window.insert((q.output_tokens, seq));
        }
        wq.pending.push_back(PendingQuery {
            seq,
            id: q.id,
            arrival_s: q.arrival_s,
            m: q.input_tokens,
            n: q.output_tokens,
            row,
        });
        Some((sid.0, w))
    }

    /// The event-heap main loop over the source: one-query lookahead on
    /// arrivals, lazy-stamp due events for dispatches — the same
    /// control flow as `engine::simulate_batched_with_tables`.
    fn run(
        mut self,
        source: &mut dyn QuerySource,
        limit: usize,
        policy: &mut dyn Policy,
        cache: &mut RowCache,
        sink: &mut dyn FnMut(u64, &QueryOutcome),
    ) -> Result<StreamReport, String> {
        let mut stamps: Vec<Vec<u64>> =
            self.queues.iter().map(|sq| vec![0u64; sq.len()]).collect();
        let mut heap: BinaryHeap<Reverse<DueEvent>> = BinaryHeap::new();
        let mut upcoming: Option<(u64, Query)> = None;
        let mut pulled = 0usize;
        let mut last_arrival = f64::NEG_INFINITY;

        loop {
            // keep exactly one arrival buffered
            if upcoming.is_none() && pulled < limit {
                match source.next_query()? {
                    Some(q) => {
                        let seq = pulled as u64;
                        check_sorted(&q, last_arrival, seq)?;
                        last_arrival = q.arrival_s;
                        upcoming = Some((seq, q));
                        pulled += 1;
                    }
                    // source ended early: stop pulling, drain the queues
                    None => pulled = limit,
                }
            }
            let next_arrival = upcoming.as_ref().map_or(f64::INFINITY, |(_, q)| q.arrival_s);

            // earliest live due event, discarding stale ones lazily
            let mut due: Option<(f64, usize, usize)> = None;
            while let Some(&Reverse(ev)) = heap.peek() {
                let (s, w) = (ev.s as usize, ev.w as usize);
                if ev.stamp != stamps[s][w] {
                    heap.pop();
                    continue;
                }
                due = Some((ev.ready, s, w));
                break;
            }

            if let Some((ready, s, w)) = due {
                // dispatch everything due before the next arrival; an
                // arrival exactly at the deadline misses the batch
                if ready <= next_arrival {
                    heap.pop();
                    self.dispatch(ready, s, w, cache, sink);
                    self.refresh(&mut stamps, &mut heap, s, w);
                    continue;
                }
            }

            // no batch due before the next arrival: route it (a shed
            // arrival joins no queue, so there is nothing to refresh)
            let Some((seq, q)) = upcoming.take() else { break };
            if let Some((s, w)) = self.route_arrival(policy, seq, &q, cache, sink) {
                self.refresh(&mut stamps, &mut heap, s, w);
            }
        }

        // run any still-live episodes to retirement (every queue is
        // empty now, so their boundaries carry no admission decisions)
        self.drain_episodes(cache, sink);
        debug_assert!(self.ep_resident.is_empty(), "every episode member must be attributed");

        let Self { opts, totals, .. } = self;
        Ok(totals.finish(policy.name(), opts, cache.n_unique_rows()))
    }
}

/// Streaming twin of `engine::emit_episode_outcomes`: finalize a fully
/// retired episode, reclaiming each member's parked [`PendingQuery`]
/// for outcome attribution. Admissionless episodes replay the static
/// attribution verbatim from their founding cost (bit-identical to a
/// static dispatch); episodes with admissions attribute the booked
/// merged-phase energy by token share.
fn emit_stream_episode(
    batch_table: &BatchTable,
    s: usize,
    totals: &mut StreamTotals,
    ep_resident: &mut HashMap<u64, PendingQuery>,
    cache: &RowCache,
    sink: &mut dyn FnMut(u64, &QueryOutcome),
    ep: Episode,
) {
    debug_assert!(ep.live.is_empty(), "finalize only fully retired episodes");
    if !ep.admitted_any {
        let cost = &ep.founding_cost;
        let e_batch = batch_table.energy_j(cost);
        let batch_tokens: f64 = ep.founding.iter().map(|&(_, m, n)| (m + n) as f64).sum();
        for (k, &(seq, m, n)) in ep.founding.iter().enumerate() {
            let p = ep_resident.remove(&(seq as u64)).expect("episode member is resident");
            let share = (m + n) as f64 / batch_tokens;
            let o = QueryOutcome {
                query_id: p.id,
                system: s,
                arrival_s: p.arrival_s,
                start_s: ep.start_s,
                finish_s: ep.start_s + cost.member_finish_s[k],
                service_s: cost.member_finish_s[k],
                energy_j: e_batch * share,
            };
            totals.acc.push(p.seq, &o, cache.energy_j(p.row, s));
            sink(p.seq, &o);
        }
        return;
    }
    let total = ep.booked_energy_j;
    let tokens = ep.total_tokens();
    for d in &ep.done {
        let p = ep_resident.remove(&(d.qi as u64)).expect("episode member is resident");
        let share = (d.m + d.n) as f64 / tokens;
        let finish = ep.start_s + d.finish_rel;
        let o = QueryOutcome {
            query_id: p.id,
            system: s,
            arrival_s: p.arrival_s,
            start_s: d.admit_s,
            finish_s: finish,
            service_s: finish - d.admit_s,
            energy_j: total * share,
        };
        totals.acc.push(p.seq, &o, cache.energy_j(p.row, s));
        sink(p.seq, &o);
    }
}

/// Which worker queue a routed query joins — `engine::pick_worker_queue`
/// over streaming queues: least load (node's remaining busy time plus
/// queued serial runtimes), index order, strict `<`, single-queue
/// layouts skip the scan (and its float arithmetic) entirely.
fn pick_stream_queue(
    node: &NodeState,
    queues: &[StreamWorkerQueue],
    t: f64,
    cache: &RowCache,
    system: usize,
) -> usize {
    if queues.len() == 1 {
        return 0;
    }
    let mut best = 0usize;
    let mut best_load = f64::INFINITY;
    for (w, wq) in queues.iter().enumerate() {
        let backlog: f64 = wq.pending.iter().map(|p| cache.runtime_s(p.row, system)).sum();
        let load = (node.node_free_at[w] - t).max(0.0) + backlog;
        if load < best_load {
            best_load = load;
            best = w;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::PolicyConfig;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::cost_table::CostTable;
    use crate::perf::model::PerfModel;
    use crate::sched::overload::AdmissionConfig;
    use crate::sched::policy::build_policy;
    use crate::sim::engine::{simulate, simulate_with_table};
    use crate::workload::generator::{Arrival, TraceGenerator};
    use crate::workload::source::SliceSource;

    fn energy() -> EnergyModel {
        EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()))
    }

    fn trace(n: usize) -> Vec<Query> {
        TraceGenerator::new(Arrival::Poisson { rate: 30.0 }, 13).generate(n)
    }

    /// Serial streaming is bit-identical to the materialized serial
    /// engine: every outcome field, every report total.
    #[test]
    fn serial_stream_matches_materialized_engine_bitwise() {
        let queries = trace(600);
        let systems = system_catalog();
        let em = energy();
        let opts = SimOptions { include_idle_energy: true, ..Default::default() };

        let table = CostTable::build(&queries, &systems, &em);
        let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let want = simulate_with_table(&queries, &systems, p.as_mut(), &table, &opts);

        let mut streamed: Vec<(u64, QueryOutcome)> = Vec::new();
        let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
        let got = simulate_stream_with_sink(
            &mut SliceSource::new(&queries),
            queries.len(),
            &systems,
            p.as_mut(),
            &em,
            &opts,
            &mut |seq, o| streamed.push((seq, *o)),
        )
        .unwrap();

        assert_eq!(got.queries, want.outcomes.len() as u64);
        assert_eq!(streamed.len(), want.outcomes.len());
        for (seq, o) in &streamed {
            let w = &want.outcomes[*seq as usize];
            assert_eq!(o.query_id, w.query_id);
            assert_eq!(o.system, w.system);
            assert_eq!(o.start_s.to_bits(), w.start_s.to_bits());
            assert_eq!(o.finish_s.to_bits(), w.finish_s.to_bits());
            assert_eq!(o.service_s.to_bits(), w.service_s.to_bits());
            assert_eq!(o.energy_j.to_bits(), w.energy_j.to_bits());
        }
        assert_eq!(got.makespan_s.to_bits(), want.makespan_s.to_bits());
        assert_eq!(got.total_service_s.to_bits(), want.total_service_s.to_bits());
        assert_eq!(got.total_energy_j.to_bits(), want.total_energy_j.to_bits());
        assert_eq!(got.idle_energy_j.to_bits(), want.idle_energy_j.to_bits());
        assert_eq!(got.serial_energy_j.to_bits(), want.serial_energy_j.to_bits());
        assert_eq!(got.rerouted, want.rerouted);
        for (gs, ws) in got.systems.iter().zip(&want.systems) {
            assert_eq!(gs.queries, ws.queries);
            assert_eq!(gs.busy_s.to_bits(), ws.busy_s.to_bits());
            assert_eq!(gs.energy_j.to_bits(), ws.energy_j.to_bits());
        }
        assert!((got.mean_latency_s - want.mean_latency_s()).abs() < 1e-9);
        assert!(got.energy_conserved());
        assert!(got.unique_shapes > 0 && got.unique_shapes <= queries.len());
        assert!(got.peak_pending >= 1);
    }

    /// Batched streaming is bit-identical to the materialized event-heap
    /// engine, across formation policies and queue models.
    #[test]
    fn batched_stream_matches_materialized_engine_bitwise() {
        let queries = trace(400);
        let mut systems = system_catalog();
        systems[1].count = 2;
        let em = energy();
        for (formation, queues) in [
            (FormationPolicy::FifoPrefix, QueueModel::PerWorker),
            (FormationPolicy::ShapeAware { n_bins: 4 }, QueueModel::PerWorker),
            (FormationPolicy::ShapeAware { n_bins: 4 }, QueueModel::PerClass),
        ] {
            let opts = SimOptions {
                include_idle_energy: true,
                batching: Some(
                    BatchingOptions::new(6, 0.15).with_formation(formation).with_queues(queues),
                ),
                ..Default::default()
            };
            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let want = simulate(&queries, &systems, p.as_mut(), &em, &opts);

            let mut streamed: Vec<(u64, QueryOutcome)> = Vec::new();
            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let got = simulate_stream_with_sink(
                &mut SliceSource::new(&queries),
                queries.len(),
                &systems,
                p.as_mut(),
                &em,
                &opts,
                &mut |seq, o| streamed.push((seq, *o)),
            )
            .unwrap();

            assert_eq!(streamed.len(), want.outcomes.len(), "{formation:?}/{queues:?}");
            streamed.sort_unstable_by_key(|&(seq, _)| seq);
            for (seq, o) in &streamed {
                let w = &want.outcomes[*seq as usize];
                assert_eq!(o.query_id, w.query_id);
                assert_eq!(o.system, w.system);
                assert_eq!(o.start_s.to_bits(), w.start_s.to_bits());
                assert_eq!(o.finish_s.to_bits(), w.finish_s.to_bits());
                assert_eq!(o.energy_j.to_bits(), w.energy_j.to_bits());
            }
            assert_eq!(got.makespan_s.to_bits(), want.makespan_s.to_bits());
            assert_eq!(got.total_energy_j.to_bits(), want.total_energy_j.to_bits());
            assert_eq!(got.total_service_s.to_bits(), want.total_service_s.to_bits());
            assert_eq!(got.serial_energy_j.to_bits(), want.serial_energy_j.to_bits());
            assert_eq!(got.rerouted, want.rerouted);
            for (s, (gb, wb)) in got.batches.iter().zip(&want.batches).enumerate() {
                assert_eq!(gb.dispatches, wb.dispatches, "system {s}");
                assert_eq!(gb.size_hist, wb.size_hist, "system {s}");
                assert_eq!(gb.straggler_decode_steps, wb.straggler_decode_steps);
            }
            assert!(got.energy_conserved());
        }
    }

    #[test]
    fn limit_caps_the_pull() {
        let queries = trace(200);
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::RoundRobin, em.clone(), &systems);
        let r = simulate_stream(
            &mut SliceSource::new(&queries),
            50,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions::default(),
        )
        .unwrap();
        assert_eq!(r.queries, 50);
    }

    #[test]
    fn source_ending_before_limit_is_fine() {
        let queries = trace(30);
        let systems = system_catalog();
        let em = energy();
        let opts =
            SimOptions { batching: Some(BatchingOptions::new(4, 0.1)), ..Default::default() };
        let mut p = build_policy(&PolicyConfig::RoundRobin, em.clone(), &systems);
        let r = simulate_stream(
            &mut SliceSource::new(&queries),
            1_000_000,
            &systems,
            p.as_mut(),
            &em,
            &opts,
        )
        .unwrap();
        assert_eq!(r.queries, 30);
        assert_eq!(r.routing_counts().iter().sum::<u64>(), 30);
    }

    /// Streaming admission mirrors the materialized engines
    /// decision-for-decision: identical per-tenant shed ledgers,
    /// bit-identical totals, and arrivals are conserved
    /// (served + shed == pulled).
    #[test]
    fn admission_stream_matches_materialized_and_conserves() {
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 500.0 }, 7).generate(2000);
        let systems = system_catalog();
        let em = energy();
        let admission = AdmissionConfig { queue_budget: 8, ..AdmissionConfig::default() };
        for batching in [None, Some(BatchingOptions::new(4, 0.05))] {
            let opts = SimOptions {
                include_idle_energy: true,
                batching,
                admission: Some(admission.clone()),
                ..Default::default()
            };
            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let want = simulate(&queries, &systems, p.as_mut(), &em, &opts);

            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let got = simulate_stream(
                &mut SliceSource::new(&queries),
                queries.len(),
                &systems,
                p.as_mut(),
                &em,
                &opts,
            )
            .unwrap();

            assert_eq!(got.shed, want.shed, "batching={batching:?}");
            assert!(got.total_shed() > 0, "an overloaded trace must shed");
            assert_eq!(got.queries + got.total_shed(), queries.len() as u64);
            assert_eq!(got.total_energy_j.to_bits(), want.total_energy_j.to_bits());
            assert_eq!(got.makespan_s.to_bits(), want.makespan_s.to_bits());
            assert_eq!(got.serial_energy_j.to_bits(), want.serial_energy_j.to_bits());
            assert_eq!(got.total_service_s.to_bits(), want.total_service_s.to_bits());
            assert!(got.energy_conserved());
            assert!(got.shed_rate() > 0.0 && got.shed_rate() < 1.0);
        }
    }

    /// The streaming fault loop is bit-identical to the materialized
    /// fault engine — outcomes, totals, ledger, retry counts, wasted
    /// joules — across serial and batched configurations.
    #[test]
    fn faulted_stream_matches_materialized_engine_bitwise() {
        use crate::sched::faults::{FaultConfig, RetryPolicy};
        let queries = TraceGenerator::new(Arrival::Poisson { rate: 60.0 }, 13).generate(1000);
        let systems = system_catalog();
        let em = energy();
        let faults = FaultConfig {
            mtbf_s: 40.0,
            mttr_s: 5.0,
            slow_mtbf_s: 90.0,
            slow_duration_s: 15.0,
            slow_factor: 2.0,
            retry: RetryPolicy { max_attempts: 3, ..RetryPolicy::default() },
            ..FaultConfig::default()
        };
        for batching in [None, Some(BatchingOptions::new(4, 0.05))] {
            let opts = SimOptions {
                include_idle_energy: true,
                batching,
                faults: Some(faults.clone()),
                ..Default::default()
            };
            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let want = simulate(&queries, &systems, p.as_mut(), &em, &opts);
            assert!(want.total_retries() > 0, "the schedule must actually crash something");

            let mut streamed: Vec<(u64, QueryOutcome)> = Vec::new();
            let mut p = build_policy(&PolicyConfig::Cost { lambda: 1.0 }, em.clone(), &systems);
            let got = simulate_stream_with_sink(
                &mut SliceSource::new(&queries),
                queries.len(),
                &systems,
                p.as_mut(),
                &em,
                &opts,
                &mut |seq, o| streamed.push((seq, *o)),
            )
            .unwrap();

            // materialized outcomes are sorted by trace index and hold
            // exactly the served queries; sorting the sink's stream by
            // sequence number lines the two up one-to-one
            assert_eq!(streamed.len(), want.outcomes.len(), "batching={batching:?}");
            streamed.sort_unstable_by_key(|&(seq, _)| seq);
            for ((_, o), w) in streamed.iter().zip(&want.outcomes) {
                assert_eq!(o.query_id, w.query_id);
                assert_eq!(o.system, w.system);
                assert_eq!(o.start_s.to_bits(), w.start_s.to_bits());
                assert_eq!(o.finish_s.to_bits(), w.finish_s.to_bits());
                assert_eq!(o.service_s.to_bits(), w.service_s.to_bits());
                assert_eq!(o.energy_j.to_bits(), w.energy_j.to_bits());
            }
            assert_eq!(got.makespan_s.to_bits(), want.makespan_s.to_bits());
            assert_eq!(got.total_energy_j.to_bits(), want.total_energy_j.to_bits());
            assert_eq!(got.total_service_s.to_bits(), want.total_service_s.to_bits());
            assert_eq!(got.serial_energy_j.to_bits(), want.serial_energy_j.to_bits());
            assert_eq!(got.wasted_energy_j.to_bits(), want.wasted_energy_j.to_bits());
            assert_eq!(got.retries, want.retries);
            assert_eq!(got.shed, want.shed);
            for (gs, ws) in got.systems.iter().zip(&want.systems) {
                assert_eq!(gs.queries, ws.queries);
                assert_eq!(gs.busy_s.to_bits(), ws.busy_s.to_bits());
                assert_eq!(gs.energy_j.to_bits(), ws.energy_j.to_bits());
            }
            // conservation: every pull is served or abandoned, and the
            // energy ledger balances once wasted joules are counted
            assert_eq!(
                got.queries + got.total_abandoned(),
                queries.len() as u64,
                "batching={batching:?}"
            );
            assert!(got.energy_conserved());
        }
    }

    #[test]
    fn unsorted_stream_is_an_error() {
        let queries = vec![
            Query { arrival_s: 1.0, ..Query::new(0, 8, 8) },
            Query { arrival_s: 0.5, ..Query::new(1, 8, 8) },
        ];
        let systems = system_catalog();
        let em = energy();
        let mut p = build_policy(&PolicyConfig::RoundRobin, em.clone(), &systems);
        let err = simulate_stream(
            &mut SliceSource::new(&queries),
            2,
            &systems,
            p.as_mut(),
            &em,
            &SimOptions::default(),
        )
        .unwrap_err();
        assert!(err.contains("not sorted"), "{err}");
    }
}
