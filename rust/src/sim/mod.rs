//! Discrete-event cluster simulator: runs a trace through a policy and a
//! cluster model, producing the energy/runtime totals behind Figs. 4–5
//! and the headline result.

pub mod cluster;
pub mod continuous;
pub mod queueing;
pub mod engine;
pub mod report;
pub mod stream;

pub use cluster::{ClusterState, NodeState};
pub use engine::{
    simulate, simulate_batched_with_tables, simulate_with_table, BatchMode, BatchingOptions,
    SimOptions,
};
pub use report::{BatchStats, ShedLedger, ShedStats, SimReport, StreamingOutcomes};
pub use stream::{simulate_stream, simulate_stream_with_sink, StreamReport};
