//! Calibration: recover perf-model constants from measured samples.
//!
//! The paper calibrates its Eq. 9/10 predictors from benchmark sweeps
//! (§5.2: randomized repeated trials until a 95% CI of ±0.5 s or 25
//! trials). We reproduce both the trial protocol and the fitting step so
//! a user with a real testbed CSV can refit our catalog.

use crate::hw::spec::SystemSpec;
use crate::model::LlmSpec;
use crate::perf::model::PerfModel;
use crate::util::rng::Xoshiro256;
use crate::util::stats::{linregress, Welford};

/// One measured (or simulated-measured) trial.
#[derive(Clone, Copy, Debug)]
pub struct Trial {
    pub m: u32,
    pub n: u32,
    pub runtime_s: f64,
    pub energy_j: f64,
}

/// Fitted linear decode model: runtime ≈ a + b·n at fixed m.
#[derive(Clone, Copy, Debug)]
pub struct DecodeFit {
    pub base_s: f64,
    pub per_token_s: f64,
    pub r2: f64,
}

/// Fit the decode-side constants from an output-token sweep at fixed m.
pub fn fit_decode(trials: &[Trial]) -> DecodeFit {
    let xs: Vec<f64> = trials.iter().map(|t| t.n as f64).collect();
    let ys: Vec<f64> = trials.iter().map(|t| t.runtime_s).collect();
    let (a, b, r2) = linregress(&xs, &ys);
    DecodeFit { base_s: a, per_token_s: b, r2 }
}

/// Implied effective bandwidth (B/s) from a decode fit.
pub fn implied_bandwidth(fit: &DecodeFit, llm: &LlmSpec, mean_ctx: f64) -> f64 {
    llm.decode_bytes(mean_ctx) / fit.per_token_s
}

/// The paper's §5.2.3 trial protocol: repeat a noisy measurement until
/// the 95% CI half-width on the mean runtime is within `tol_s`, or
/// `max_trials` is reached. Returns (mean, trials_used).
pub fn run_trials<F>(mut measure: F, tol_s: f64, max_trials: u32) -> (f64, u32)
where
    F: FnMut() -> f64,
{
    let mut w = Welford::new();
    for i in 1..=max_trials {
        w.push(measure());
        if i >= 2 && w.ci95_half_width() <= tol_s {
            return (w.mean(), i);
        }
    }
    (w.mean(), max_trials)
}

/// Generate noisy synthetic trials from the perf model (measurement noise
/// ~ N(0, rel_noise·R)) — the test harness for the fitting code and the
/// input to the `calibrate` subcommand's demo mode.
pub fn synthetic_sweep(
    perf: &PerfModel,
    spec: &SystemSpec,
    points: &[(u32, u32)],
    rel_noise: f64,
    rng: &mut Xoshiro256,
) -> Vec<Trial> {
    points
        .iter()
        .map(|&(m, n)| {
            let c = perf.query_cost(spec, m, n);
            let noise_r = 1.0 + rel_noise * rng.normal();
            let noise_e = 1.0 + rel_noise * rng.normal();
            Trial {
                m,
                n,
                runtime_s: (c.runtime_s * noise_r).max(1e-6),
                energy_j: (c.energy_j * noise_e).max(1e-6),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::{system_catalog, SystemId};
    use crate::model::llm_catalog;

    #[test]
    fn fit_recovers_decode_rate() {
        let perf = PerfModel::new(llm_catalog()[1].clone());
        let specs = system_catalog();
        let a100 = &specs[SystemId::SWING_A100.0];
        let mut rng = Xoshiro256::seed_from(1);
        let pts: Vec<(u32, u32)> = [8u32, 16, 32, 64, 128, 256, 512]
            .iter()
            .map(|&n| (32u32, n))
            .collect();
        let trials = synthetic_sweep(&perf, a100, &pts, 0.01, &mut rng);
        let fit = fit_decode(&trials);
        assert!(fit.r2 > 0.99, "r2={}", fit.r2);
        // per-token time should match the model's mid-sweep step time ±15%
        let want = perf.decode_step_time(a100, 32.0 + 128.0);
        assert!(
            (fit.per_token_s - want).abs() / want < 0.15,
            "fit {} vs model {want}",
            fit.per_token_s
        );
        // implied bandwidth lands near the spec's
        let bw = implied_bandwidth(&fit, &perf.llm, 160.0);
        assert!((bw - a100.mem_bw).abs() / a100.mem_bw < 0.2, "bw={bw:e}");
    }

    #[test]
    fn trial_protocol_stops_early_when_quiet() {
        let mut i = 0u32;
        let (mean, used) = run_trials(
            || {
                i += 1;
                1.0 + 0.001 * (i % 2) as f64
            },
            0.5,
            25,
        );
        assert!(used < 25, "should stop early, used {used}");
        assert!((mean - 1.0).abs() < 0.01);
    }

    #[test]
    fn trial_protocol_caps_at_max() {
        let mut rng = Xoshiro256::seed_from(2);
        let (_, used) = run_trials(|| rng.normal_with(10.0, 5.0), 0.001, 25);
        assert_eq!(used, 25); // paper's cap (§5.2.3 condition 2)
    }
}
