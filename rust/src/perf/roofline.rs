//! Roofline helpers (§5.3 cites Williams et al.'s model for the observed
//! throughput shape) + the L1 kernel VMEM/MXU estimators recorded in
//! EXPERIMENTS.md §Perf.

use crate::hw::spec::SystemSpec;

/// Arithmetic intensity (FLOP/byte) at which a system flips from
/// bandwidth-bound to compute-bound.
pub fn ridge_point(spec: &SystemSpec) -> f64 {
    spec.compute_flops / spec.mem_bw
}

/// Attainable FLOP/s at a given arithmetic intensity.
pub fn attainable_flops(spec: &SystemSpec, intensity: f64) -> f64 {
    (spec.mem_bw * intensity).min(spec.compute_flops)
}

/// VMEM footprint estimate (bytes) of the Pallas flash-attention kernel
/// for given tile sizes — documents the L1 design choice (16 MB budget).
pub fn flash_attention_vmem(block_q: usize, block_k: usize, d_head: usize) -> usize {
    let f = 4; // fp32 accumulate
    let q_tile = block_q * d_head * f;
    let kv_tiles = 2 * block_k * d_head * f;
    let acc = block_q * d_head * f;
    let softmax_state = 2 * block_q * f; // m, l
    let s_tile = block_q * block_k * f;
    // ×2 on streamed tiles for double buffering headroom
    q_tile + 2 * kv_tiles + acc + softmax_state + s_tile
}

/// MXU utilization *estimate* for the flash kernel: fraction of issued
/// MACs that land on the 128×128 systolic array given tile shapes.
pub fn flash_attention_mxu_utilization(block_q: usize, block_k: usize, d_head: usize) -> f64 {
    // each matmul tile is (block_q × d_head) · (d_head × block_k);
    // the MXU wants each dim ≥ 128 — fractional occupancy otherwise.
    let occ = |dim: usize| (dim as f64 / 128.0).min(1.0);
    occ(block_q) * occ(block_k) * occ(d_head)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;

    #[test]
    fn ridge_point_ordering() {
        let specs = system_catalog();
        // A100 ridge ≈ 56e12/1150e9 ≈ 49 FLOP/B; decode intensity (~1) is
        // far below → decode is bandwidth-bound on every system.
        for s in &specs {
            assert!(ridge_point(s) > 2.0, "{}", s.name);
        }
    }

    #[test]
    fn attainable_clamps_at_peak() {
        let specs = system_catalog();
        let a100 = &specs[1];
        assert!(attainable_flops(a100, 0.1) < a100.compute_flops);
        assert_eq!(attainable_flops(a100, 1e6), a100.compute_flops);
    }

    #[test]
    fn default_tiles_fit_vmem_budget() {
        // attention.py defaults: block_q = block_k = 32, d_head = 32
        let bytes = flash_attention_vmem(32, 32, 32);
        assert!(bytes < 16 * 1024 * 1024, "VMEM estimate {bytes} over budget");
        // and a production-shaped tile (128×128×128) still fits
        let big = flash_attention_vmem(128, 128, 128);
        assert!(big < 16 * 1024 * 1024, "{big}");
    }

    #[test]
    fn mxu_estimate_monotone_in_tiles() {
        let small = flash_attention_mxu_utilization(32, 32, 32);
        let big = flash_attention_mxu_utilization(128, 128, 128);
        assert!(small < big);
        assert!((big - 1.0).abs() < 1e-9);
    }
}
