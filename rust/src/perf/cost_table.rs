//! Precomputed per-(query, system) cost table — the shared substrate
//! under the simulator and every sweep.
//!
//! `E(m,n,s)` and `R(m,n,s)` are pure functions of the query and the
//! system (Eq. 1): nothing about thresholds, λ, or queue state changes
//! them. The seed code nevertheless re-evaluated the analytical model
//! for every (query, grid-point) pair, making Fig. 4/5-style sweeps
//! O(|trace| × |grid|) model evaluations. A [`CostTable`] evaluates the
//! model **once per (query, system)** — in parallel across cores via
//! [`crate::util::par`] — and its consumers
//! ([`crate::sim::engine::simulate_with_table`],
//! [`crate::experiments::runner`]) then read costs in O(1). The
//! threshold sweeps use the sibling per-query precompute
//! [`crate::experiments::sweeps::pair_costs`], which bakes the
//! threshold router's small→big fallback into its cells; any change to
//! evaluation semantics here (e.g. attribution handling) must be
//! mirrored there.
//!
//! Cells are stored exactly as the direct evaluation would produce them
//! (same code path, same f64 operation order), so table-backed results
//! are bit-identical to per-query evaluation — equivalence is enforced
//! by `rust/tests/cost_table_equivalence.rs`.

use super::energy::{Attribution, EnergyModel};
use super::model::{BatchCost, Feasibility};
use crate::hw::spec::SystemSpec;
use crate::util::check::atomic::{AtomicU64, Ordering};
use crate::util::check::{Mutex, OnceLock};
use crate::util::par::par_map;
use crate::workload::Query;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

/// Cost of one query on one system. Infeasible cells carry `NaN` costs
/// and a non-`Ok` feasibility; consumers must check feasibility before
/// reading costs (the simulator and sweeps do).
#[derive(Clone, Copy, Debug)]
pub struct CostCell {
    pub energy_j: f64,
    pub runtime_s: f64,
    pub feasibility: Feasibility,
}

/// Table of [`CostCell`]s for a trace × catalog, plus the per-query
/// energy-cheapest feasible system (the simulator's re-route fallback
/// target).
///
/// Two physical layouts share one lookup API:
///
/// - [`CostTable::build`] — **dense**: one row of cells per query.
/// - [`CostTable::build_dedup`] — **(m, n)-deduplicated**: one row per
///   *unique* token pair, with a per-query row index. Alpaca traces
///   repeat token pairs heavily, so for fleet studies that multiply
///   hundreds of `SystemSpec::count` variants against one trace this
///   shrinks build cost by the trace's repeat factor while every
///   accessor stays O(1). Cells are evaluated through the identical
///   code path, so the two layouts are bit-identical cell-for-cell
///   (property-tested in `rust/tests/properties.rs`).
///
/// ```
/// use hetsched::hw::catalog::system_catalog;
/// use hetsched::model::llm_catalog;
/// use hetsched::perf::cost_table::CostTable;
/// use hetsched::perf::energy::EnergyModel;
/// use hetsched::perf::model::PerfModel;
/// use hetsched::workload::Query;
///
/// let systems = system_catalog();
/// let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
/// // two queries with the same (m, n) = one unique shape
/// let queries = vec![Query::new(0, 32, 64), Query::new(1, 32, 64)];
/// let dense = CostTable::build(&queries, &systems, &energy);
/// let dedup = CostTable::build_dedup(&queries, &systems, &energy);
/// assert_eq!(dense.n_unique_rows(), 2);
/// assert_eq!(dedup.n_unique_rows(), 1);
/// assert_eq!(dense.energy_j(1, 1), dedup.energy_j(1, 1)); // same cells
/// ```
#[derive(Clone, Debug)]
pub struct CostTable {
    n_systems: usize,
    n_queries: usize,
    /// row of `cells` describing each query: the identity map for the
    /// dense layout, the first-occurrence shape index when deduplicated
    row_of: Vec<usize>,
    /// `n_rows × n_systems` cells, row-major
    cells: Vec<CostCell>,
    /// energy-cheapest feasible system per row
    cheapest: Vec<Option<usize>>,
    /// which attribution ([`Attribution::Total`] / [`Attribution::Net`])
    /// the energy column was built with
    pub attribution: Attribution,
}

/// One row of cells for a `(m, n)` pair over the catalog — the single
/// evaluation path both [`CostTable::build`] and
/// [`CostTable::build_dedup`] go through, which is what makes the two
/// layouts bit-identical.
fn eval_row(m: u32, n: u32, systems: &[SystemSpec], energy: &EnergyModel) -> Vec<CostCell> {
    systems
        .iter()
        .map(|spec| {
            let feasibility = energy.perf.feasibility(spec, m, n);
            if feasibility == Feasibility::Ok {
                let (energy_j, runtime_s) = energy.energy_and_runtime(spec, m, n);
                CostCell { energy_j, runtime_s, feasibility }
            } else {
                CostCell { energy_j: f64::NAN, runtime_s: f64::NAN, feasibility }
            }
        })
        .collect()
}

/// Argmin energy over feasible systems, scanning in catalog order with
/// strict `<` — the same tie-break the simulator's direct fallback scan
/// used.
fn cheapest_of(row: &[CostCell]) -> Option<usize> {
    let mut best = None;
    let mut best_e = f64::INFINITY;
    for (i, c) in row.iter().enumerate() {
        if c.feasibility == Feasibility::Ok && c.energy_j < best_e {
            best_e = c.energy_j;
            best = Some(i);
        }
    }
    best
}

impl CostTable {
    /// Evaluate the perf/energy model once per (query, system), fanned
    /// across cores. Deterministic: identical to the serial build.
    pub fn build(queries: &[Query], systems: &[SystemSpec], energy: &EnergyModel) -> Self {
        let n_systems = systems.len();
        let rows: Vec<Vec<CostCell>> =
            par_map(queries, |q| eval_row(q.input_tokens, q.output_tokens, systems, energy));
        let mut cells = Vec::with_capacity(queries.len() * n_systems);
        let mut cheapest = Vec::with_capacity(queries.len());
        for row in rows {
            cheapest.push(cheapest_of(&row));
            cells.extend(row);
        }
        Self {
            n_systems,
            n_queries: queries.len(),
            row_of: (0..queries.len()).collect(),
            cells,
            cheapest,
            attribution: energy.attribution,
        }
    }

    /// The (m, n)-deduplicated build: evaluate the model once per
    /// **unique** token pair (in first-occurrence order, fanned across
    /// cores) and map every query to its shape's row. `E(m,n,s)` and
    /// `R(m,n,s)` depend only on the pair, so the cells are bit-identical
    /// to the dense build's — heavy-repeat traces (Alpaca) just stop
    /// paying for the same evaluation over and over. All accessors keep
    /// their per-query indexing and O(1) cost.
    pub fn build_dedup(queries: &[Query], systems: &[SystemSpec], energy: &EnergyModel) -> Self {
        let n_systems = systems.len();
        let mut shape_row: HashMap<(u32, u32), usize> = HashMap::new();
        let mut shapes: Vec<(u32, u32)> = Vec::new();
        let mut row_of = Vec::with_capacity(queries.len());
        for q in queries {
            let key = (q.input_tokens, q.output_tokens);
            let row = *shape_row.entry(key).or_insert_with(|| {
                shapes.push(key);
                shapes.len() - 1
            });
            row_of.push(row);
        }
        let rows: Vec<Vec<CostCell>> =
            par_map(&shapes, |&(m, n)| eval_row(m, n, systems, energy));
        let mut cells = Vec::with_capacity(shapes.len() * n_systems);
        let mut cheapest = Vec::with_capacity(shapes.len());
        for row in rows {
            cheapest.push(cheapest_of(&row));
            cells.extend(row);
        }
        Self {
            n_systems,
            n_queries: queries.len(),
            row_of,
            cells,
            cheapest,
            attribution: energy.attribution,
        }
    }

    #[inline]
    fn idx(&self, query: usize, system: usize) -> usize {
        debug_assert!(system < self.n_systems);
        self.row_of[query] * self.n_systems + system
    }

    #[inline]
    pub fn cell(&self, query: usize, system: usize) -> &CostCell {
        &self.cells[self.idx(query, system)]
    }

    /// `E(m,n,s)` in joules (NaN when infeasible).
    #[inline]
    pub fn energy_j(&self, query: usize, system: usize) -> f64 {
        self.cell(query, system).energy_j
    }

    /// `R(m,n,s)` in seconds (NaN when infeasible).
    #[inline]
    pub fn runtime_s(&self, query: usize, system: usize) -> f64 {
        self.cell(query, system).runtime_s
    }

    #[inline]
    pub fn feasibility(&self, query: usize, system: usize) -> Feasibility {
        self.cell(query, system).feasibility
    }

    #[inline]
    pub fn is_feasible(&self, query: usize, system: usize) -> bool {
        self.feasibility(query, system) == Feasibility::Ok
    }

    /// The energy-cheapest feasible system for `query`, if any — the
    /// simulator's fallback when a policy routes somewhere infeasible.
    #[inline]
    pub fn cheapest_feasible(&self, query: usize) -> Option<usize> {
        self.cheapest[self.row_of[query]]
    }

    pub fn n_queries(&self) -> usize {
        self.n_queries
    }

    pub fn n_systems(&self) -> usize {
        self.n_systems
    }

    /// Physical rows actually evaluated and stored: equals
    /// [`Self::n_queries`] for the dense layout, the number of distinct
    /// `(m, n)` pairs for [`Self::build_dedup`]. The ratio to
    /// `n_queries` is the build-cost shrink factor dedup bought.
    pub fn n_unique_rows(&self) -> usize {
        self.cheapest.len()
    }
}

/// Lazily populated per-shape cost rows — the streaming counterpart of
/// [`CostTable`]. A [`CostTable`] needs the whole trace up front; a
/// streaming run (`sim::stream`) sees queries one at a time and cannot
/// know the shape set in advance. `RowCache` evaluates a row the first
/// time a `(m, n)` pair appears — through the **same** [`eval_row`] /
/// [`cheapest_of`] path as both `CostTable` layouts, so cells are
/// bit-identical to table-backed runs — and every later query with that
/// shape is a hash lookup. Memory is O(unique shapes × systems),
/// independent of trace length: the dedup observation that makes
/// [`CostTable::build_dedup`] cheap is what makes million-query
/// streaming bounded.
///
/// Single-threaded by design (`&mut self`): the streaming engines are
/// sequential loops, so there is no lock to pay.
#[derive(Clone, Debug)]
pub struct RowCache {
    energy: EnergyModel,
    systems: Vec<SystemSpec>,
    shape_row: HashMap<(u32, u32), usize>,
    /// `n_rows × n_systems` cells, row-major — same layout as
    /// [`CostTable::cells`]
    cells: Vec<CostCell>,
    cheapest: Vec<Option<usize>>,
}

impl RowCache {
    pub fn new(energy: EnergyModel, systems: &[SystemSpec]) -> Self {
        Self {
            energy,
            systems: systems.to_vec(),
            shape_row: HashMap::new(),
            cells: Vec::new(),
            cheapest: Vec::new(),
        }
    }

    /// Row index for a shape, evaluating the model on first sight.
    pub fn row(&mut self, m: u32, n: u32) -> usize {
        if let Some(&r) = self.shape_row.get(&(m, n)) {
            return r;
        }
        let row = eval_row(m, n, &self.systems, &self.energy);
        let r = self.cheapest.len();
        self.cheapest.push(cheapest_of(&row));
        self.cells.extend(row);
        self.shape_row.insert((m, n), r);
        r
    }

    #[inline]
    pub fn cell(&self, row: usize, system: usize) -> &CostCell {
        debug_assert!(system < self.systems.len());
        &self.cells[row * self.systems.len() + system]
    }

    /// `E(m,n,s)` in joules (NaN when infeasible).
    #[inline]
    pub fn energy_j(&self, row: usize, system: usize) -> f64 {
        self.cell(row, system).energy_j
    }

    /// `R(m,n,s)` in seconds (NaN when infeasible).
    #[inline]
    pub fn runtime_s(&self, row: usize, system: usize) -> f64 {
        self.cell(row, system).runtime_s
    }

    #[inline]
    pub fn is_feasible(&self, row: usize, system: usize) -> bool {
        self.cell(row, system).feasibility == Feasibility::Ok
    }

    /// The energy-cheapest feasible system for a row, if any.
    #[inline]
    pub fn cheapest_feasible(&self, row: usize) -> Option<usize> {
        self.cheapest[row]
    }

    pub fn n_systems(&self) -> usize {
        self.systems.len()
    }

    /// Which attribution the energy column carries.
    pub fn attribution(&self) -> Attribution {
        self.energy.attribution
    }

    /// Rows evaluated so far — the cache's whole memory footprint.
    pub fn n_unique_rows(&self) -> usize {
        self.cheapest.len()
    }
}

/// Composition key of a batch on a system: the member `(m, n)` pairs in
/// dispatch order (bucket representatives when the table is bucketed).
type BatchKey = (usize, Vec<(u32, u32)>);

/// Quantile bucket grid over `(m, n)`, derived once from a trace — the
/// ROADMAP's bucketed-`BatchTable` layout. Exact compositions rarely
/// repeat on long Alpaca traces (the token distributions are heavy-
/// tailed), so the exact-key memo's hit rate is near zero; mapping each
/// member to its quantile bin collapses near-identical compositions into
/// one cell and turns that into real sharing, at a small modeling-error
/// cost (costs are evaluated at the bin's lower edge).
///
/// Bucket representatives are the bin **lower** edges, clamped to the
/// member's own value, so a representative is always `<=` the actual
/// member in both coordinates. Feasibility is monotone in `(m, n)`
/// (growing a query never fixes an OOM — pinned by
/// `prop_feasibility_monotone`), so any batch whose actual members pass
/// the exact feasibility check has a feasible representative — a
/// bucketed cost cell can never go NaN on a feasible batch.
#[derive(Clone, Debug)]
pub struct BucketSpec {
    /// ascending bin lower edges for input tokens
    m_edges: Vec<u32>,
    /// ascending bin lower edges for output tokens
    n_edges: Vec<u32>,
}

impl BucketSpec {
    /// Derive `bins` equal-population (quantile) bins per axis from the
    /// trace's token distributions. Duplicate edges (heavy repeated
    /// values) are collapsed, so the effective bin count may be lower.
    pub fn from_trace(queries: &[Query], bins: usize) -> Self {
        Self {
            m_edges: quantile_edges(queries.iter().map(|q| q.input_tokens).collect(), bins),
            n_edges: quantile_edges(queries.iter().map(|q| q.output_tokens).collect(), bins),
        }
    }

    /// The bucket representative of a member: per axis, the largest bin
    /// lower edge `<=` the value (clamped to the value itself for inputs
    /// below every edge, e.g. compositions outside the deriving trace).
    pub fn representative(&self, m: u32, n: u32) -> (u32, u32) {
        (lower_edge(&self.m_edges, m).min(m), lower_edge(&self.n_edges, n).min(n))
    }

    /// Distinct bins per axis (after dedup): `(m_bins, n_bins)`.
    pub fn bin_counts(&self) -> (usize, usize) {
        (self.m_edges.len(), self.n_edges.len())
    }
}

fn quantile_edges(mut vals: Vec<u32>, bins: usize) -> Vec<u32> {
    assert!(bins >= 1, "bucket spec needs at least one bin");
    if vals.is_empty() {
        return vec![0];
    }
    vals.sort_unstable();
    let mut edges: Vec<u32> = (0..bins).map(|b| vals[b * vals.len() / bins]).collect();
    edges.dedup();
    edges
}

/// Largest edge `<=` v (edges ascending; v below the first edge maps to
/// the first edge — callers clamp).
fn lower_edge(edges: &[u32], v: u32) -> u32 {
    match edges.binary_search(&v) {
        Ok(i) => edges[i],
        Err(0) => edges[0],
        Err(i) => edges[i - 1],
    }
}

/// Memoized batch-cost table — the batched sibling of [`CostTable`].
///
/// ```
/// use hetsched::hw::catalog::system_catalog;
/// use hetsched::model::llm_catalog;
/// use hetsched::perf::cost_table::BatchTable;
/// use hetsched::perf::energy::EnergyModel;
/// use hetsched::perf::model::PerfModel;
///
/// let systems = system_catalog();
/// let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
/// let table = BatchTable::new(energy, &systems);
/// let first = table.cost(1, &[(32, 64), (16, 32)]);
/// let again = table.cost(1, &[(32, 64), (16, 32)]); // memo hit
/// assert_eq!(table.hits(), 1);
/// assert_eq!(first.runtime_s, again.runtime_s);
/// ```
///
/// Batch compositions are data-dependent (they emerge from arrivals and
/// queue state), so they cannot be enumerated up front the way per-query
/// cells can. Instead the table memoizes by composition: the model runs
/// **once per (composition, system)** and every later hit — the same
/// batch shape recurring within a trace, or across the grid points of a
/// [`crate::experiments::runner::batching_sweep`] sharing one table — is
/// a lookup. [`BatchTable::bucketed`] keys by quantile-bin signature
/// instead of exact composition (see [`BucketSpec`]), which raises hit
/// rates from near zero to useful on long traces.
///
/// ## Concurrency
///
/// Sweep grid points fan over [`crate::util::par`] against one shared
/// instance, so the cache is **lock-striped**: keys hash to one of
/// [`BATCH_TABLE_SHARDS`] independently locked maps, and a lookup takes
/// exactly one shard-lock acquisition (the pre-PR-5 layout funneled the
/// whole worker pool through a single global `Mutex<HashMap>`, which
/// serialized hit-heavy sweeps — `hetsched bench` measures the
/// difference). Each cell is an [`OnceLock`] slot, so two workers
/// missing the same key agree on one slot under the shard lock and only
/// one of them evaluates the model — the other blocks on the cell
/// (in-flight de-duplication; the pre-PR-5 miss path evaluated outside
/// the lock and could run the model twice for the same key, making
/// [`Self::evaluations`] drift under contention). Bucketed cells are
/// evaluated at the deterministic bin representative — never at
/// whichever actual composition got there first — so results are
/// identical at any core count. The shard mutexes, in-flight slots, and
/// statistics counters all come from [`crate::util::check`] (plain
/// `std::sync` re-exports in normal builds), so the whole
/// miss/hit/dedup protocol is exhaustively explored by the model-check
/// suite (`rust/tests/model_check.rs`) under `--features model-check`.
///
/// ## Bounded memoization
///
/// Batch compositions are data-dependent, so on long streaming traces
/// the exact-key memo grows with the number of *distinct* compositions
/// seen — unbounded in trace length. [`Self::with_capacity`] bounds
/// residency with a per-shard **clock** (second-chance) eviction: every
/// hit sets a referenced bit, every insert past capacity sweeps the
/// shard's ring, clearing bits until it finds an unreferenced victim.
/// Eviction never changes *values* — a re-miss of an evicted key
/// re-evaluates through the identical path and lands bit-identical —
/// only the hit/evaluation trajectory. The capacity is split evenly
/// across the [`BATCH_TABLE_SHARDS`] stripes (rounded up, minimum one
/// cell per shard), so the global bound is approximate by at most one
/// ring slot per shard.
pub struct BatchTable {
    energy: EnergyModel,
    systems: Vec<SystemSpec>,
    buckets: Option<BucketSpec>,
    /// lock-striped cache: `shards[hash(key) % BATCH_TABLE_SHARDS]`
    shards: Vec<Shard>,
    /// resident-cell bound per shard; 0 = unbounded (the default)
    shard_capacity: usize,
    /// the user-facing total capacity `with_capacity` was given
    capacity: usize,
    lookups: AtomicU64,
    hits: AtomicU64,
    evaluations: AtomicU64,
    evictions: AtomicU64,
}

/// One memo cell: initialized exactly once, by whichever worker won the
/// insert; concurrent missers block on it instead of re-evaluating.
type BatchSlot = Arc<OnceLock<Arc<BatchCost>>>;

/// One resident cell plus its clock (second-chance) bit.
struct ShardEntry {
    slot: BatchSlot,
    /// set on every hit, cleared by the sweeping clock hand; a cell is
    /// evicted only after a full hand pass without a hit
    referenced: bool,
}

/// One lock stripe of the cache: the resident map plus the clock ring
/// of resident keys (`ring`/`hand` stay empty in unbounded mode).
struct ShardState {
    map: HashMap<BatchKey, ShardEntry>,
    /// resident keys in insertion-slot order — the clock's sweep ring
    ring: Vec<BatchKey>,
    /// next ring position the clock hand examines
    hand: usize,
}

type Shard = Mutex<ShardState>;

impl ShardState {
    fn new() -> Self {
        Self { map: HashMap::new(), ring: Vec::new(), hand: 0 }
    }

    /// Clock sweep: advance the hand, giving referenced cells a second
    /// chance, until an unreferenced victim is found; remove it from the
    /// map and return its ring slot for reuse. Terminates within two
    /// passes (the first pass clears every referenced bit).
    fn evict_one(&mut self) -> usize {
        loop {
            let entry =
                self.map.get_mut(&self.ring[self.hand]).expect("ring keys stay resident");
            if entry.referenced {
                entry.referenced = false;
                self.hand = (self.hand + 1) % self.ring.len();
            } else {
                self.map.remove(&self.ring[self.hand]);
                let slot = self.hand;
                self.hand = (self.hand + 1) % self.ring.len();
                return slot;
            }
        }
    }
}

/// Lock stripes of a [`BatchTable`] (power of two: the shard index is a
/// mask of the key hash). 64 stripes keep the collision probability of
/// a full worker pool low while staying cache-friendly.
pub const BATCH_TABLE_SHARDS: usize = 64;

/// Shard index of a key: its hash masked to the stripe count. Uses the
/// std `DefaultHasher` with a fixed state, so sharding is deterministic
/// across runs (the per-shard `HashMap`s keep their own randomized
/// SipHash states — determinism of *results* never depends on layout).
fn shard_index(key: &BatchKey) -> usize {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) & (BATCH_TABLE_SHARDS - 1)
}

impl BatchTable {
    /// Exact-composition memoization (bit-identical to direct
    /// [`crate::perf::model::PerfModel::batch_cost`] evaluation).
    pub fn new(energy: EnergyModel, systems: &[SystemSpec]) -> Self {
        Self {
            energy,
            systems: systems.to_vec(),
            buckets: None,
            shards: (0..BATCH_TABLE_SHARDS).map(|_| Mutex::new(ShardState::new())).collect(),
            shard_capacity: 0,
            capacity: 0,
            lookups: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            evaluations: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Quantile-bucketed memoization: costs are keyed and evaluated at
    /// each member's bucket representative.
    pub fn bucketed(energy: EnergyModel, systems: &[SystemSpec], buckets: BucketSpec) -> Self {
        Self { buckets: Some(buckets), ..Self::new(energy, systems) }
    }

    /// Bound resident cells to roughly `capacity` across all shards with
    /// clock (second-chance) eviction; `0` leaves the memo unbounded.
    /// This is what makes batched streaming truly
    /// O(pending + unique shapes): without it the exact-composition memo
    /// grows with every distinct composition the trace produces.
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self.shard_capacity =
            if capacity == 0 { 0 } else { capacity.div_ceil(BATCH_TABLE_SHARDS).max(1) };
        self
    }

    /// The total-capacity bound [`Self::with_capacity`] was given
    /// (0 = unbounded).
    pub fn memo_capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_bucketed(&self) -> bool {
        self.buckets.is_some()
    }

    /// Which attribution the [`Self::energy_j`] accessor reports.
    pub fn attribution(&self) -> Attribution {
        self.energy.attribution
    }

    pub fn n_systems(&self) -> usize {
        self.systems.len()
    }

    /// The energy model behind every cell. The continuous engine prices
    /// decode-step spans and step-boundary admissions through this exact
    /// model so episode costs stay consistent with the memoized static
    /// costs.
    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy
    }

    /// The spec of system `idx` (panics when out of range, like
    /// [`Self::cost`]).
    pub fn system_spec(&self, idx: usize) -> &SystemSpec {
        &self.systems[idx]
    }

    /// Cost of dispatching `members` as one batch on `system`, memoized
    /// per composition (per bucket signature when bucketed).
    /// Deterministic: a hit returns exactly what the miss computed, and
    /// bucketed cells are always evaluated at the bin representative —
    /// independent of which actual composition reached the bucket first.
    ///
    /// One shard-lock acquisition per lookup. Two workers missing the
    /// same key both find (or one inserts, the other finds) a single
    /// [`OnceLock`] slot under that lock, so the model runs exactly once
    /// per cell even under contention and [`Self::evaluations`] stays
    /// exact; the model evaluation itself runs with the lock released.
    pub fn cost(&self, system: usize, members: &[(u32, u32)]) -> Arc<BatchCost> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let keyed: Vec<(u32, u32)> = match &self.buckets {
            None => members.to_vec(),
            Some(b) => members.iter().map(|&(m, n)| b.representative(m, n)).collect(),
        };
        let key: BatchKey = (system, keyed);
        let mut shard = self.shards[shard_index(&key)].lock().unwrap();
        if let Some(entry) = shard.map.get_mut(&key) {
            entry.referenced = true;
            let slot = Arc::clone(&entry.slot);
            drop(shard);
            self.hits.fetch_add(1, Ordering::Relaxed);
            // the inserting worker may still be evaluating: get_or_init
            // blocks until the cell is set (and evaluates here only if
            // that worker panicked out of the model)
            return Arc::clone(slot.get_or_init(|| self.evaluate(system, &key.1)));
        }
        let pairs = key.1.clone();
        let slot = Arc::new(OnceLock::new());
        if self.shard_capacity > 0 && shard.ring.len() >= self.shard_capacity {
            // at capacity: the clock hand picks a victim and its ring
            // slot is reused for the incoming key
            let reuse = shard.evict_one();
            shard.ring[reuse] = key.clone();
            self.evictions.fetch_add(1, Ordering::Relaxed);
        } else if self.shard_capacity > 0 {
            shard.ring.push(key.clone());
        }
        // new cells start unreferenced: a cell that is never hit again is
        // the first to go once the hand comes around
        shard.map.insert(key, ShardEntry { slot: Arc::clone(&slot), referenced: false });
        drop(shard);
        // evaluate with the shard unlocked so other keys of this stripe
        // aren't serialized on the model
        Arc::clone(slot.get_or_init(|| self.evaluate(system, &pairs)))
    }

    /// The single model-evaluation path behind every cell, counted
    /// exactly once per [`OnceLock`] initialization.
    fn evaluate(&self, system: usize, pairs: &[(u32, u32)]) -> Arc<BatchCost> {
        self.evaluations.fetch_add(1, Ordering::Relaxed);
        Arc::new(self.energy.perf.batch_cost(&self.systems[system], pairs))
    }

    /// Cache lookups served so far (both modes).
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Lookups that were cache hits.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Fraction of lookups served from the memo (0 when none yet). Near
    /// zero for exact keys on long Alpaca traces; the point of
    /// [`Self::bucketed`] is to make this real.
    pub fn hit_rate(&self) -> f64 {
        let l = self.lookups();
        if l == 0 {
            0.0
        } else {
            self.hits() as f64 / l as f64
        }
    }

    /// The batch's energy under this table's attribution.
    pub fn energy_j(&self, cost: &BatchCost) -> f64 {
        match self.energy.attribution {
            Attribution::Total => cost.energy_j,
            Attribution::Net => cost.net_energy_j,
        }
    }

    /// Longest feasible prefix of `members` on `system` (joint KV
    /// footprint check): the simulator trims oversized batches to this
    /// length and leaves the tail queued. At least 1 when the first
    /// member is individually feasible.
    pub fn feasible_prefix(&self, system: usize, members: &[(u32, u32)]) -> usize {
        let spec = &self.systems[system];
        let mut k = members.len();
        while k > 1 && self.energy.perf.batch_feasibility(spec, &members[..k]) != Feasibility::Ok {
            k -= 1;
        }
        k
    }

    /// Model evaluations performed so far — one per distinct
    /// (composition, system) cell, **exactly**, even under concurrent
    /// misses of the same key (the in-flight slot de-duplicates them;
    /// regression-tested by hammering one key from the whole pool).
    /// Under a [`Self::with_capacity`] bound, a key evicted and
    /// re-missed evaluates again, so `evaluations` can exceed the
    /// distinct-key count by up to [`Self::evictions`].
    pub fn evaluations(&self) -> usize {
        self.evaluations.load(Ordering::Relaxed) as usize
    }

    /// Cells evicted by the clock hand so far (0 when unbounded).
    /// Reported by the sweeps alongside [`Self::hits`] /
    /// [`Self::lookups`]: a high eviction rate at a given capacity means
    /// the working set of distinct compositions does not fit.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::model::PerfModel;
    use crate::workload::alpaca::AlpacaModel;

    fn table(attribution: Attribution) -> (Vec<Query>, Vec<SystemSpec>, EnergyModel, CostTable) {
        let queries = AlpacaModel::default().trace(17, 2_000);
        let systems = system_catalog();
        let energy =
            EnergyModel::with_attribution(PerfModel::new(llm_catalog()[1].clone()), attribution);
        let t = CostTable::build(&queries, &systems, &energy);
        (queries, systems, energy, t)
    }

    #[test]
    fn cells_match_direct_model_evaluation_exactly() {
        for attribution in [Attribution::Total, Attribution::Net] {
            let (queries, systems, energy, t) = table(attribution);
            assert_eq!(t.n_queries(), queries.len());
            assert_eq!(t.n_systems(), systems.len());
            for (qi, q) in queries.iter().enumerate() {
                for (si, spec) in systems.iter().enumerate() {
                    let feas = energy.perf.feasibility(spec, q.input_tokens, q.output_tokens);
                    assert_eq!(t.feasibility(qi, si), feas);
                    if feas == Feasibility::Ok {
                        let e = energy.energy(spec, q.input_tokens, q.output_tokens);
                        let r = energy.runtime(spec, q.input_tokens, q.output_tokens);
                        assert_eq!(t.energy_j(qi, si), e, "energy cell ({qi},{si})");
                        assert_eq!(t.runtime_s(qi, si), r, "runtime cell ({qi},{si})");
                    } else {
                        assert!(t.energy_j(qi, si).is_nan());
                    }
                }
            }
        }
    }

    #[test]
    fn cheapest_feasible_is_the_energy_argmin() {
        let (queries, systems, energy, t) = table(Attribution::Total);
        for (qi, q) in queries.iter().enumerate() {
            let mut best = None;
            let mut best_e = f64::INFINITY;
            for (i, spec) in systems.iter().enumerate() {
                if energy.perf.feasibility(spec, q.input_tokens, q.output_tokens)
                    == Feasibility::Ok
                {
                    let e = energy.energy(spec, q.input_tokens, q.output_tokens);
                    if e < best_e {
                        best_e = e;
                        best = Some(i);
                    }
                }
            }
            assert_eq!(t.cheapest_feasible(qi), best, "query {qi}");
        }
    }

    #[test]
    fn batch_table_memoizes_per_composition() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy.clone(), &systems);
        let members = [(32u32, 64u32), (16, 32)];
        let a = t.cost(1, &members);
        assert_eq!(t.evaluations(), 1);
        let b = t.cost(1, &members);
        assert_eq!(t.evaluations(), 1, "repeat composition must be a cache hit");
        assert!(Arc::ptr_eq(&a, &b));
        // same composition on another system is a distinct bucket
        let _ = t.cost(2, &members);
        assert_eq!(t.evaluations(), 2);
        // and the cached cell matches direct evaluation exactly
        let direct = energy.perf.batch_cost(&systems[1], &members);
        assert_eq!(a.runtime_s, direct.runtime_s);
        assert_eq!(a.energy_j, direct.energy_j);
        assert_eq!(a.member_finish_s, direct.member_finish_s);
    }

    #[test]
    fn feasible_prefix_trims_joint_oom() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy, &systems);
        // V100 (index 2): (32, 1024) fits alone but not four at once
        let members = [(32u32, 1024u32); 4];
        let k = t.feasible_prefix(2, &members);
        assert!(k >= 1 && k < 4, "prefix {k}");
        assert_eq!(t.cost(2, &members[..k]).feasibility, Feasibility::Ok);
        // a comfortably small batch is untrimmed
        assert_eq!(t.feasible_prefix(1, &[(8, 8), (8, 8)]), 2);
    }

    #[test]
    fn bucketed_table_collapses_near_identical_compositions() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let trace = AlpacaModel::default().trace(5, 4_000);
        let spec = BucketSpec::from_trace(&trace, 8);
        let (mb, nb) = spec.bin_counts();
        assert!(mb >= 2 && nb >= 2, "alpaca trace must yield multiple bins ({mb}, {nb})");
        let t = BatchTable::bucketed(energy.clone(), &systems, spec.clone());

        // two compositions that differ inside their bins share one cell
        let a = t.cost(1, &[(40, 70), (300, 20)]);
        let b_members = [(41u32, 71u32), (301, 21)];
        let same_bucket = spec.representative(40, 70) == spec.representative(41, 71)
            && spec.representative(300, 20) == spec.representative(301, 21);
        let b = t.cost(1, &b_members);
        if same_bucket {
            assert!(Arc::ptr_eq(&a, &b), "same bucket signature must be one cell");
            assert_eq!(t.hits(), 1);
            assert!(t.hit_rate() > 0.0);
        }
        assert_eq!(t.lookups(), 2);

        // deterministic: the cell is evaluated at the representative, so
        // a fresh table seeded by the *other* composition agrees exactly
        let t2 = BatchTable::bucketed(energy, &systems, spec);
        let b2 = t2.cost(1, &b_members);
        let a2 = t2.cost(1, &[(40, 70), (300, 20)]);
        assert_eq!(a.runtime_s, a2.runtime_s);
        assert_eq!(a.energy_j, a2.energy_j);
        if same_bucket {
            assert!(Arc::ptr_eq(&a2, &b2));
        }
    }

    #[test]
    fn bucket_representative_never_exceeds_member() {
        let trace = AlpacaModel::default().trace(9, 2_000);
        let spec = BucketSpec::from_trace(&trace, 6);
        for q in &trace {
            let (rm, rn) = spec.representative(q.input_tokens, q.output_tokens);
            assert!(rm <= q.input_tokens && rn <= q.output_tokens, "({rm},{rn}) repr of query {q:?}");
        }
        // values outside the deriving trace clamp safely (incl. below
        // the lowest edge)
        let (rm, rn) = spec.representative(0, 0);
        assert_eq!((rm, rn), (0, 0));
    }

    /// Feasibility safety: a batch whose actual members pass the exact
    /// joint check always has a feasible (<= componentwise)
    /// representative, so bucketed costs of feasible batches are never
    /// NaN.
    #[test]
    fn bucketed_cost_of_feasible_batch_is_finite() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let trace = AlpacaModel::default().trace(3, 2_000);
        let spec = BucketSpec::from_trace(&trace, 8);
        let t = BatchTable::bucketed(energy.clone(), &systems, spec);
        // V100: (32, 1024) fits alone but four don't — trim exactly like
        // the exact table, then cost the trimmed batch
        let members = [(32u32, 1024u32); 4];
        let k = t.feasible_prefix(2, &members);
        assert!(k >= 1 && k < 4);
        let c = t.cost(2, &members[..k]);
        assert_eq!(c.feasibility, Feasibility::Ok);
        assert!(c.runtime_s.is_finite() && c.energy_j.is_finite());
        assert_eq!(c.member_finish_s.len(), k);
    }

    #[test]
    fn exact_table_hit_rate_counters() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy, &systems);
        assert!(!t.is_bucketed());
        assert_eq!(t.hit_rate(), 0.0, "no lookups yet");
        let _ = t.cost(1, &[(8, 8)]);
        let _ = t.cost(1, &[(8, 8)]);
        let _ = t.cost(1, &[(8, 9)]);
        assert_eq!(t.lookups(), 3);
        assert_eq!(t.hits(), 1);
        assert!((t.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    /// ISSUE 4 acceptance: the deduplicated layout is bit-identical to
    /// the dense build on a repeated-pair Alpaca trace — every cell,
    /// every fallback target — while storing far fewer rows.
    #[test]
    fn dedup_layout_matches_dense_on_alpaca_trace() {
        let queries = AlpacaModel::default().trace(2024, 6_000);
        let systems = system_catalog();
        for attribution in [Attribution::Total, Attribution::Net] {
            let energy = EnergyModel::with_attribution(
                PerfModel::new(llm_catalog()[1].clone()),
                attribution,
            );
            let dense = CostTable::build(&queries, &systems, &energy);
            let dedup = CostTable::build_dedup(&queries, &systems, &energy);
            assert_eq!(dedup.n_queries(), dense.n_queries());
            assert_eq!(dedup.n_systems(), dense.n_systems());
            // Alpaca token pairs repeat heavily: dedup must store
            // strictly fewer rows than queries
            assert!(
                dedup.n_unique_rows() < queries.len(),
                "no repeats found in {} queries ({} rows)",
                queries.len(),
                dedup.n_unique_rows()
            );
            assert_eq!(dense.n_unique_rows(), queries.len());
            for qi in 0..queries.len() {
                assert_eq!(dedup.cheapest_feasible(qi), dense.cheapest_feasible(qi), "query {qi}");
                for si in 0..systems.len() {
                    assert_eq!(dedup.feasibility(qi, si), dense.feasibility(qi, si));
                    if dense.is_feasible(qi, si) {
                        // bit-identical, not approximately equal
                        assert_eq!(
                            dedup.energy_j(qi, si).to_bits(),
                            dense.energy_j(qi, si).to_bits(),
                            "energy cell ({qi},{si})"
                        );
                        assert_eq!(
                            dedup.runtime_s(qi, si).to_bits(),
                            dense.runtime_s(qi, si).to_bits(),
                            "runtime cell ({qi},{si})"
                        );
                    } else {
                        assert!(dedup.energy_j(qi, si).is_nan());
                    }
                }
            }
        }
    }

    #[test]
    fn dedup_layout_handles_all_unique_and_all_same() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        // all-identical trace: one row
        let same: Vec<Query> = (0..50u64).map(|id| Query::new(id, 40, 40)).collect();
        let t = CostTable::build_dedup(&same, &systems, &energy);
        assert_eq!(t.n_unique_rows(), 1);
        assert_eq!(t.n_queries(), 50);
        assert_eq!(t.energy_j(0, 1), t.energy_j(49, 1));
        // all-unique trace: as many rows as queries
        let uniq: Vec<Query> = (0..50u64).map(|id| Query::new(id, 8 + id as u32, 8)).collect();
        let t = CostTable::build_dedup(&uniq, &systems, &energy);
        assert_eq!(t.n_unique_rows(), 50);
    }

    /// ISSUE 5 satellite regression: the pre-PR-5 miss path (get-lock,
    /// evaluate unlocked, insert-lock) could evaluate the same key twice
    /// when two pool workers missed together. Hammer one key from the
    /// whole `util::par` worker pool: the in-flight slot must collapse
    /// every concurrent miss into exactly one evaluation, and the
    /// counters must be exact — `evaluations == 1`,
    /// `hits == lookups − 1`.
    #[test]
    fn concurrent_misses_on_one_key_evaluate_once() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy.clone(), &systems);
        let members = [(48u32, 96u32), (16, 512)];
        let n = 4_000usize;
        let costs = crate::util::par::par_map_range(n, |_| t.cost(1, &members));
        assert_eq!(t.evaluations(), 1, "one key must evaluate exactly once");
        assert_eq!(t.lookups(), n as u64);
        assert_eq!(t.hits(), n as u64 - 1, "every lookup but the winner is a hit");
        // every caller got the same cell, bit-identical to direct eval
        let direct = energy.perf.batch_cost(&systems[1], &members);
        for c in &costs {
            assert!(Arc::ptr_eq(c, &costs[0]));
            assert_eq!(c.energy_j.to_bits(), direct.energy_j.to_bits());
            assert_eq!(c.runtime_s.to_bits(), direct.runtime_s.to_bits());
        }
    }

    /// Sharded cells are bit-identical to direct model evaluation under
    /// concurrent mixed-key access, and the counters stay exact:
    /// `evaluations` = distinct keys, `hits + evaluations = lookups`.
    #[test]
    fn concurrent_mixed_keys_have_exact_counters() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy.clone(), &systems);
        // 16 distinct (composition, system) cells, looked up 1000 times
        // from the pool
        let pool: Vec<(usize, Vec<(u32, u32)>)> = (0..16u32)
            .map(|i| (1 + (i as usize % 2), vec![(8 + i, 16 + i), (8, 8 + i % 4)]))
            .collect();
        let n = 1_000usize;
        crate::util::par::par_map_range(n, |i| {
            let (sys, members) = &pool[i % pool.len()];
            t.cost(*sys, members)
        });
        assert_eq!(t.evaluations(), 16, "one evaluation per distinct (composition, system)");
        assert_eq!(t.lookups(), n as u64);
        assert_eq!(t.hits() + t.evaluations() as u64, t.lookups());
        // and every cell matches direct evaluation exactly
        for (sys, members) in &pool {
            let cell = t.cost(*sys, members);
            let direct = energy.perf.batch_cost(&systems[*sys], members);
            assert_eq!(cell.energy_j.to_bits(), direct.energy_j.to_bits());
            assert_eq!(cell.runtime_s.to_bits(), direct.runtime_s.to_bits());
            assert_eq!(cell.member_finish_s, direct.member_finish_s);
        }
    }

    /// ISSUE 6: the lazy streaming row cache goes through the same
    /// evaluation path as the table builds, so cells and fallback
    /// targets are bit-identical and rows are shared across repeated
    /// shapes.
    #[test]
    fn row_cache_matches_cost_table_bitwise() {
        let queries = AlpacaModel::default().trace(31, 3_000);
        let systems = system_catalog();
        for attribution in [Attribution::Total, Attribution::Net] {
            let energy = EnergyModel::with_attribution(
                PerfModel::new(llm_catalog()[1].clone()),
                attribution,
            );
            let table = CostTable::build(&queries, &systems, &energy);
            let mut cache = RowCache::new(energy, &systems);
            assert_eq!(cache.attribution(), attribution);
            for (qi, q) in queries.iter().enumerate() {
                let row = cache.row(q.input_tokens, q.output_tokens);
                assert_eq!(cache.cheapest_feasible(row), table.cheapest_feasible(qi));
                for si in 0..systems.len() {
                    assert_eq!(cache.is_feasible(row, si), table.is_feasible(qi, si));
                    if table.is_feasible(qi, si) {
                        assert_eq!(
                            cache.energy_j(row, si).to_bits(),
                            table.energy_j(qi, si).to_bits()
                        );
                        assert_eq!(
                            cache.runtime_s(row, si).to_bits(),
                            table.runtime_s(qi, si).to_bits()
                        );
                    } else {
                        assert!(cache.energy_j(row, si).is_nan());
                    }
                }
            }
            // lazily discovered rows == the dedup build's unique shapes
            let dedup = CostTable::build_dedup(&queries, &systems, &cache.energy);
            assert_eq!(cache.n_unique_rows(), dedup.n_unique_rows());
            assert!(cache.n_unique_rows() < queries.len());
        }
    }

    #[test]
    fn row_cache_repeated_shape_reuses_row() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let mut cache = RowCache::new(energy, &systems);
        let a = cache.row(32, 64);
        let b = cache.row(16, 32);
        let c = cache.row(32, 64);
        assert_eq!(a, c);
        assert_ne!(a, b);
        assert_eq!(cache.n_unique_rows(), 2);
        assert_eq!(cache.n_systems(), systems.len());
    }

    /// ISSUE 7 satellite: a capacity-bounded table stays bit-identical
    /// to the unbounded one on every returned cost — eviction only
    /// changes the hit/evaluation trajectory — while holding residency
    /// at the per-shard bound and counting every eviction.
    #[test]
    fn bounded_memo_evicts_but_stays_bit_identical() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        // tiny bound: one resident cell per shard
        let bounded = BatchTable::new(energy.clone(), &systems).with_capacity(1);
        assert_eq!(bounded.memo_capacity(), 1);
        let unbounded = BatchTable::new(energy, &systems);
        assert_eq!(unbounded.memo_capacity(), 0);
        // far more distinct compositions than capacity, revisited twice
        let pool: Vec<Vec<(u32, u32)>> =
            (0..400u32).map(|i| vec![(8 + i % 97, 16 + i % 53), (8 + i % 13, 8)]).collect();
        for pass in 0..2 {
            for members in &pool {
                let a = bounded.cost(1, members);
                let b = unbounded.cost(1, members);
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "pass {pass}");
                assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits(), "pass {pass}");
                assert_eq!(a.member_finish_s, b.member_finish_s);
            }
        }
        assert!(bounded.evictions() > 0, "400 keys through 64 cells must evict");
        // residency respects the bound: re-missed evictions re-evaluate
        assert!(bounded.evaluations() > unbounded.evaluations());
        assert_eq!(
            bounded.hits() + bounded.evaluations() as u64,
            bounded.lookups(),
            "every lookup is a hit or an evaluation"
        );
        assert_eq!(unbounded.evictions(), 0);
    }

    /// Clock second-chance: with capacity for the working set, a
    /// hot key keeps its referenced bit set and is never evicted even as
    /// cold keys churn past it.
    #[test]
    fn clock_eviction_gives_hot_keys_a_second_chance() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        // capacity 128 = 2 cells per shard: one slot for the hot key,
        // one for churn that lands in the same shard
        let t = BatchTable::new(energy, &systems).with_capacity(128);
        let hot = [(32u32, 64u32)];
        let _ = t.cost(1, &hot);
        let evals_after_hot = t.evaluations();
        for i in 0..300u32 {
            // touch the hot key between cold misses so its bit stays set
            let _ = t.cost(1, &[(100 + i, 16)]);
            let _ = t.cost(1, &hot);
        }
        // the hot key was evaluated exactly once: every later lookup hit
        let cold_evals = t.evaluations() - evals_after_hot;
        assert!(cold_evals >= 300 - 64, "cold keys churned: {cold_evals}");
        let lookups = t.lookups();
        assert_eq!(lookups, 601);
        assert!(t.hits() >= 300, "hot key must keep hitting, got {}", t.hits());
    }

    #[test]
    fn infeasible_everywhere_has_no_fallback() {
        // a 100K-token generation's KV cache exceeds every catalog
        // system's memory (and the M1's generation cap)
        let queries = vec![Query::new(0, 8, 100_000)];
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = CostTable::build(&queries, &systems, &energy);
        assert_eq!(t.cheapest_feasible(0), None);
        assert!((0..systems.len()).all(|s| !t.is_feasible(0, s)));
    }
}
