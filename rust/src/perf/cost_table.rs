//! Precomputed per-(query, system) cost table — the shared substrate
//! under the simulator and every sweep.
//!
//! `E(m,n,s)` and `R(m,n,s)` are pure functions of the query and the
//! system (Eq. 1): nothing about thresholds, λ, or queue state changes
//! them. The seed code nevertheless re-evaluated the analytical model
//! for every (query, grid-point) pair, making Fig. 4/5-style sweeps
//! O(|trace| × |grid|) model evaluations. A [`CostTable`] evaluates the
//! model **once per (query, system)** — in parallel across cores via
//! [`crate::util::par`] — and its consumers
//! ([`crate::sim::engine::simulate_with_table`],
//! [`crate::experiments::runner`]) then read costs in O(1). The
//! threshold sweeps use the sibling per-query precompute
//! [`crate::experiments::sweeps::pair_costs`], which bakes the
//! threshold router's small→big fallback into its cells; any change to
//! evaluation semantics here (e.g. attribution handling) must be
//! mirrored there.
//!
//! Cells are stored exactly as the direct evaluation would produce them
//! (same code path, same f64 operation order), so table-backed results
//! are bit-identical to per-query evaluation — equivalence is enforced
//! by `rust/tests/cost_table_equivalence.rs`.

use super::energy::{Attribution, EnergyModel};
use super::model::{BatchCost, Feasibility};
use crate::hw::spec::SystemSpec;
use crate::util::par::par_map;
use crate::workload::Query;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Cost of one query on one system. Infeasible cells carry `NaN` costs
/// and a non-`Ok` feasibility; consumers must check feasibility before
/// reading costs (the simulator and sweeps do).
#[derive(Clone, Copy, Debug)]
pub struct CostCell {
    pub energy_j: f64,
    pub runtime_s: f64,
    pub feasibility: Feasibility,
}

/// Dense (query-major) table of [`CostCell`]s for a trace × catalog,
/// plus the per-query energy-cheapest feasible system (the simulator's
/// re-route fallback target).
#[derive(Clone, Debug)]
pub struct CostTable {
    n_systems: usize,
    cells: Vec<CostCell>,
    cheapest: Vec<Option<usize>>,
    /// which attribution ([`Attribution::Total`] / [`Attribution::Net`])
    /// the energy column was built with
    pub attribution: Attribution,
}

impl CostTable {
    /// Evaluate the perf/energy model once per (query, system), fanned
    /// across cores. Deterministic: identical to the serial build.
    pub fn build(queries: &[Query], systems: &[SystemSpec], energy: &EnergyModel) -> Self {
        let n_systems = systems.len();
        let rows: Vec<Vec<CostCell>> = par_map(queries, |q| {
            let (m, n) = (q.input_tokens, q.output_tokens);
            systems
                .iter()
                .map(|spec| {
                    let feasibility = energy.perf.feasibility(spec, m, n);
                    if feasibility == Feasibility::Ok {
                        let (energy_j, runtime_s) = energy.energy_and_runtime(spec, m, n);
                        CostCell { energy_j, runtime_s, feasibility }
                    } else {
                        CostCell { energy_j: f64::NAN, runtime_s: f64::NAN, feasibility }
                    }
                })
                .collect()
        });
        let mut cells = Vec::with_capacity(queries.len() * n_systems);
        let mut cheapest = Vec::with_capacity(queries.len());
        for row in rows {
            // argmin energy over feasible systems, scanning in catalog
            // order with strict `<` — the same tie-break the simulator's
            // direct fallback scan used
            let mut best = None;
            let mut best_e = f64::INFINITY;
            for (i, c) in row.iter().enumerate() {
                if c.feasibility == Feasibility::Ok && c.energy_j < best_e {
                    best_e = c.energy_j;
                    best = Some(i);
                }
            }
            cheapest.push(best);
            cells.extend(row);
        }
        Self { n_systems, cells, cheapest, attribution: energy.attribution }
    }

    #[inline]
    fn idx(&self, query: usize, system: usize) -> usize {
        debug_assert!(system < self.n_systems);
        query * self.n_systems + system
    }

    #[inline]
    pub fn cell(&self, query: usize, system: usize) -> &CostCell {
        &self.cells[self.idx(query, system)]
    }

    /// `E(m,n,s)` in joules (NaN when infeasible).
    #[inline]
    pub fn energy_j(&self, query: usize, system: usize) -> f64 {
        self.cell(query, system).energy_j
    }

    /// `R(m,n,s)` in seconds (NaN when infeasible).
    #[inline]
    pub fn runtime_s(&self, query: usize, system: usize) -> f64 {
        self.cell(query, system).runtime_s
    }

    #[inline]
    pub fn feasibility(&self, query: usize, system: usize) -> Feasibility {
        self.cell(query, system).feasibility
    }

    #[inline]
    pub fn is_feasible(&self, query: usize, system: usize) -> bool {
        self.feasibility(query, system) == Feasibility::Ok
    }

    /// The energy-cheapest feasible system for `query`, if any — the
    /// simulator's fallback when a policy routes somewhere infeasible.
    #[inline]
    pub fn cheapest_feasible(&self, query: usize) -> Option<usize> {
        self.cheapest[query]
    }

    pub fn n_queries(&self) -> usize {
        if self.n_systems == 0 {
            0
        } else {
            self.cells.len() / self.n_systems
        }
    }

    pub fn n_systems(&self) -> usize {
        self.n_systems
    }
}

/// Composition key of a batch on a system: the member `(m, n)` pairs in
/// dispatch order.
type BatchKey = (usize, Vec<(u32, u32)>);

/// Memoized batch-cost table — the batched sibling of [`CostTable`].
///
/// Batch compositions are data-dependent (they emerge from arrivals and
/// queue state), so they cannot be enumerated up front the way per-query
/// cells can. Instead the table buckets by composition: the model runs
/// **once per (composition, system)** and every later hit — the same
/// batch shape recurring within a trace, or across the grid points of a
/// [`crate::experiments::runner::batching_sweep`] sharing one table — is
/// a lookup. Thread-safe: sweep grid points fan over
/// [`crate::util::par`] against one shared instance.
pub struct BatchTable {
    energy: EnergyModel,
    systems: Vec<SystemSpec>,
    cache: Mutex<HashMap<BatchKey, Arc<BatchCost>>>,
}

impl BatchTable {
    pub fn new(energy: EnergyModel, systems: &[SystemSpec]) -> Self {
        Self { energy, systems: systems.to_vec(), cache: Mutex::new(HashMap::new()) }
    }

    /// Which attribution the [`Self::energy_j`] accessor reports.
    pub fn attribution(&self) -> Attribution {
        self.energy.attribution
    }

    pub fn n_systems(&self) -> usize {
        self.systems.len()
    }

    /// Cost of dispatching `members` as one batch on `system`, memoized
    /// per composition. Deterministic: a hit returns exactly what the
    /// miss computed.
    pub fn cost(&self, system: usize, members: &[(u32, u32)]) -> Arc<BatchCost> {
        let key: BatchKey = (system, members.to_vec());
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return hit.clone();
        }
        // evaluate outside the lock so concurrent sweeps don't serialize
        // on the model; a racing duplicate computes the same value and
        // the first insert wins
        let cost = Arc::new(self.energy.perf.batch_cost(&self.systems[system], members));
        self.cache.lock().unwrap().entry(key).or_insert(cost).clone()
    }

    /// The batch's energy under this table's attribution.
    pub fn energy_j(&self, cost: &BatchCost) -> f64 {
        match self.energy.attribution {
            Attribution::Total => cost.energy_j,
            Attribution::Net => cost.net_energy_j,
        }
    }

    /// Longest feasible prefix of `members` on `system` (joint KV
    /// footprint check): the simulator trims oversized batches to this
    /// length and leaves the tail queued. At least 1 when the first
    /// member is individually feasible.
    pub fn feasible_prefix(&self, system: usize, members: &[(u32, u32)]) -> usize {
        let spec = &self.systems[system];
        let mut k = members.len();
        while k > 1 && self.energy.perf.batch_feasibility(spec, &members[..k]) != Feasibility::Ok {
            k -= 1;
        }
        k
    }

    /// Distinct (composition, system) buckets evaluated so far.
    pub fn evaluations(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::model::PerfModel;
    use crate::workload::alpaca::AlpacaModel;

    fn table(attribution: Attribution) -> (Vec<Query>, Vec<SystemSpec>, EnergyModel, CostTable) {
        let queries = AlpacaModel::default().trace(17, 2_000);
        let systems = system_catalog();
        let energy =
            EnergyModel::with_attribution(PerfModel::new(llm_catalog()[1].clone()), attribution);
        let t = CostTable::build(&queries, &systems, &energy);
        (queries, systems, energy, t)
    }

    #[test]
    fn cells_match_direct_model_evaluation_exactly() {
        for attribution in [Attribution::Total, Attribution::Net] {
            let (queries, systems, energy, t) = table(attribution);
            assert_eq!(t.n_queries(), queries.len());
            assert_eq!(t.n_systems(), systems.len());
            for (qi, q) in queries.iter().enumerate() {
                for (si, spec) in systems.iter().enumerate() {
                    let feas = energy.perf.feasibility(spec, q.input_tokens, q.output_tokens);
                    assert_eq!(t.feasibility(qi, si), feas);
                    if feas == Feasibility::Ok {
                        let e = energy.energy(spec, q.input_tokens, q.output_tokens);
                        let r = energy.runtime(spec, q.input_tokens, q.output_tokens);
                        assert_eq!(t.energy_j(qi, si), e, "energy cell ({qi},{si})");
                        assert_eq!(t.runtime_s(qi, si), r, "runtime cell ({qi},{si})");
                    } else {
                        assert!(t.energy_j(qi, si).is_nan());
                    }
                }
            }
        }
    }

    #[test]
    fn cheapest_feasible_is_the_energy_argmin() {
        let (queries, systems, energy, t) = table(Attribution::Total);
        for (qi, q) in queries.iter().enumerate() {
            let mut best = None;
            let mut best_e = f64::INFINITY;
            for (i, spec) in systems.iter().enumerate() {
                if energy.perf.feasibility(spec, q.input_tokens, q.output_tokens)
                    == Feasibility::Ok
                {
                    let e = energy.energy(spec, q.input_tokens, q.output_tokens);
                    if e < best_e {
                        best_e = e;
                        best = Some(i);
                    }
                }
            }
            assert_eq!(t.cheapest_feasible(qi), best, "query {qi}");
        }
    }

    #[test]
    fn batch_table_memoizes_per_composition() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy.clone(), &systems);
        let members = [(32u32, 64u32), (16, 32)];
        let a = t.cost(1, &members);
        assert_eq!(t.evaluations(), 1);
        let b = t.cost(1, &members);
        assert_eq!(t.evaluations(), 1, "repeat composition must be a cache hit");
        assert!(Arc::ptr_eq(&a, &b));
        // same composition on another system is a distinct bucket
        let _ = t.cost(2, &members);
        assert_eq!(t.evaluations(), 2);
        // and the cached cell matches direct evaluation exactly
        let direct = energy.perf.batch_cost(&systems[1], &members);
        assert_eq!(a.runtime_s, direct.runtime_s);
        assert_eq!(a.energy_j, direct.energy_j);
        assert_eq!(a.member_finish_s, direct.member_finish_s);
    }

    #[test]
    fn feasible_prefix_trims_joint_oom() {
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = BatchTable::new(energy, &systems);
        // V100 (index 2): (32, 1024) fits alone but not four at once
        let members = [(32u32, 1024u32); 4];
        let k = t.feasible_prefix(2, &members);
        assert!(k >= 1 && k < 4, "prefix {k}");
        assert_eq!(t.cost(2, &members[..k]).feasibility, Feasibility::Ok);
        // a comfortably small batch is untrimmed
        assert_eq!(t.feasible_prefix(1, &[(8, 8), (8, 8)]), 2);
    }

    #[test]
    fn infeasible_everywhere_has_no_fallback() {
        // a 100K-token generation's KV cache exceeds every catalog
        // system's memory (and the M1's generation cap)
        let queries = vec![Query::new(0, 8, 100_000)];
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = CostTable::build(&queries, &systems, &energy);
        assert_eq!(t.cheapest_feasible(0), None);
        assert!((0..systems.len()).all(|s| !t.is_feasible(0, s)));
    }
}
