//! Precomputed per-(query, system) cost table — the shared substrate
//! under the simulator and every sweep.
//!
//! `E(m,n,s)` and `R(m,n,s)` are pure functions of the query and the
//! system (Eq. 1): nothing about thresholds, λ, or queue state changes
//! them. The seed code nevertheless re-evaluated the analytical model
//! for every (query, grid-point) pair, making Fig. 4/5-style sweeps
//! O(|trace| × |grid|) model evaluations. A [`CostTable`] evaluates the
//! model **once per (query, system)** — in parallel across cores via
//! [`crate::util::par`] — and its consumers
//! ([`crate::sim::engine::simulate_with_table`],
//! [`crate::experiments::runner`]) then read costs in O(1). The
//! threshold sweeps use the sibling per-query precompute
//! [`crate::experiments::sweeps::pair_costs`], which bakes the
//! threshold router's small→big fallback into its cells; any change to
//! evaluation semantics here (e.g. attribution handling) must be
//! mirrored there.
//!
//! Cells are stored exactly as the direct evaluation would produce them
//! (same code path, same f64 operation order), so table-backed results
//! are bit-identical to per-query evaluation — equivalence is enforced
//! by `rust/tests/cost_table_equivalence.rs`.

use super::energy::{Attribution, EnergyModel};
use super::model::Feasibility;
use crate::hw::spec::SystemSpec;
use crate::util::par::par_map;
use crate::workload::Query;

/// Cost of one query on one system. Infeasible cells carry `NaN` costs
/// and a non-`Ok` feasibility; consumers must check feasibility before
/// reading costs (the simulator and sweeps do).
#[derive(Clone, Copy, Debug)]
pub struct CostCell {
    pub energy_j: f64,
    pub runtime_s: f64,
    pub feasibility: Feasibility,
}

/// Dense (query-major) table of [`CostCell`]s for a trace × catalog,
/// plus the per-query energy-cheapest feasible system (the simulator's
/// re-route fallback target).
#[derive(Clone, Debug)]
pub struct CostTable {
    n_systems: usize,
    cells: Vec<CostCell>,
    cheapest: Vec<Option<usize>>,
    /// which attribution ([`Attribution::Total`] / [`Attribution::Net`])
    /// the energy column was built with
    pub attribution: Attribution,
}

impl CostTable {
    /// Evaluate the perf/energy model once per (query, system), fanned
    /// across cores. Deterministic: identical to the serial build.
    pub fn build(queries: &[Query], systems: &[SystemSpec], energy: &EnergyModel) -> Self {
        let n_systems = systems.len();
        let rows: Vec<Vec<CostCell>> = par_map(queries, |q| {
            let (m, n) = (q.input_tokens, q.output_tokens);
            systems
                .iter()
                .map(|spec| {
                    let feasibility = energy.perf.feasibility(spec, m, n);
                    if feasibility == Feasibility::Ok {
                        let (energy_j, runtime_s) = energy.energy_and_runtime(spec, m, n);
                        CostCell { energy_j, runtime_s, feasibility }
                    } else {
                        CostCell { energy_j: f64::NAN, runtime_s: f64::NAN, feasibility }
                    }
                })
                .collect()
        });
        let mut cells = Vec::with_capacity(queries.len() * n_systems);
        let mut cheapest = Vec::with_capacity(queries.len());
        for row in rows {
            // argmin energy over feasible systems, scanning in catalog
            // order with strict `<` — the same tie-break the simulator's
            // direct fallback scan used
            let mut best = None;
            let mut best_e = f64::INFINITY;
            for (i, c) in row.iter().enumerate() {
                if c.feasibility == Feasibility::Ok && c.energy_j < best_e {
                    best_e = c.energy_j;
                    best = Some(i);
                }
            }
            cheapest.push(best);
            cells.extend(row);
        }
        Self { n_systems, cells, cheapest, attribution: energy.attribution }
    }

    #[inline]
    fn idx(&self, query: usize, system: usize) -> usize {
        debug_assert!(system < self.n_systems);
        query * self.n_systems + system
    }

    #[inline]
    pub fn cell(&self, query: usize, system: usize) -> &CostCell {
        &self.cells[self.idx(query, system)]
    }

    /// `E(m,n,s)` in joules (NaN when infeasible).
    #[inline]
    pub fn energy_j(&self, query: usize, system: usize) -> f64 {
        self.cell(query, system).energy_j
    }

    /// `R(m,n,s)` in seconds (NaN when infeasible).
    #[inline]
    pub fn runtime_s(&self, query: usize, system: usize) -> f64 {
        self.cell(query, system).runtime_s
    }

    #[inline]
    pub fn feasibility(&self, query: usize, system: usize) -> Feasibility {
        self.cell(query, system).feasibility
    }

    #[inline]
    pub fn is_feasible(&self, query: usize, system: usize) -> bool {
        self.feasibility(query, system) == Feasibility::Ok
    }

    /// The energy-cheapest feasible system for `query`, if any — the
    /// simulator's fallback when a policy routes somewhere infeasible.
    #[inline]
    pub fn cheapest_feasible(&self, query: usize) -> Option<usize> {
        self.cheapest[query]
    }

    pub fn n_queries(&self) -> usize {
        if self.n_systems == 0 {
            0
        } else {
            self.cells.len() / self.n_systems
        }
    }

    pub fn n_systems(&self) -> usize {
        self.n_systems
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;
    use crate::perf::model::PerfModel;
    use crate::workload::alpaca::AlpacaModel;

    fn table(attribution: Attribution) -> (Vec<Query>, Vec<SystemSpec>, EnergyModel, CostTable) {
        let queries = AlpacaModel::default().trace(17, 2_000);
        let systems = system_catalog();
        let energy =
            EnergyModel::with_attribution(PerfModel::new(llm_catalog()[1].clone()), attribution);
        let t = CostTable::build(&queries, &systems, &energy);
        (queries, systems, energy, t)
    }

    #[test]
    fn cells_match_direct_model_evaluation_exactly() {
        for attribution in [Attribution::Total, Attribution::Net] {
            let (queries, systems, energy, t) = table(attribution);
            assert_eq!(t.n_queries(), queries.len());
            assert_eq!(t.n_systems(), systems.len());
            for (qi, q) in queries.iter().enumerate() {
                for (si, spec) in systems.iter().enumerate() {
                    let feas = energy.perf.feasibility(spec, q.input_tokens, q.output_tokens);
                    assert_eq!(t.feasibility(qi, si), feas);
                    if feas == Feasibility::Ok {
                        let e = energy.energy(spec, q.input_tokens, q.output_tokens);
                        let r = energy.runtime(spec, q.input_tokens, q.output_tokens);
                        assert_eq!(t.energy_j(qi, si), e, "energy cell ({qi},{si})");
                        assert_eq!(t.runtime_s(qi, si), r, "runtime cell ({qi},{si})");
                    } else {
                        assert!(t.energy_j(qi, si).is_nan());
                    }
                }
            }
        }
    }

    #[test]
    fn cheapest_feasible_is_the_energy_argmin() {
        let (queries, systems, energy, t) = table(Attribution::Total);
        for (qi, q) in queries.iter().enumerate() {
            let mut best = None;
            let mut best_e = f64::INFINITY;
            for (i, spec) in systems.iter().enumerate() {
                if energy.perf.feasibility(spec, q.input_tokens, q.output_tokens)
                    == Feasibility::Ok
                {
                    let e = energy.energy(spec, q.input_tokens, q.output_tokens);
                    if e < best_e {
                        best_e = e;
                        best = Some(i);
                    }
                }
            }
            assert_eq!(t.cheapest_feasible(qi), best, "query {qi}");
        }
    }

    #[test]
    fn infeasible_everywhere_has_no_fallback() {
        // a 100K-token generation's KV cache exceeds every catalog
        // system's memory (and the M1's generation cap)
        let queries = vec![Query::new(0, 8, 100_000)];
        let systems = system_catalog();
        let energy = EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()));
        let t = CostTable::build(&queries, &systems, &energy);
        assert_eq!(t.cheapest_feasible(0), None);
        assert!((0..systems.len()).all(|s| !t.is_feasible(0, s)));
    }
}
