//! Energy model `E(m,n,s)` — Eq. 1's energy component, plus the hybrid
//! total-energy predictions of Eqs. 9–10.
//!
//! Thin wrapper over [`PerfModel`]: callers pick total vs. net (idle-
//! subtracted) attribution, matching the paper's mixed methodology
//! (NVML total for GPUs, RAPL net for CPUs, powermetrics impact-factor
//! for Apple Silicon).

use super::model::PerfModel;
use crate::hw::spec::SystemSpec;

/// Which energy attribution to report.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Attribution {
    /// full draw while the task runs (CPU+GPU, incl. idle floor)
    Total,
    /// idle floor subtracted (paper's RAPL methodology, Eq. 7)
    Net,
}

/// Energy model over a fixed (llm, attribution) pair.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub perf: PerfModel,
    pub attribution: Attribution,
}

impl EnergyModel {
    pub fn new(perf: PerfModel) -> Self {
        Self { perf, attribution: Attribution::Total }
    }

    pub fn with_attribution(perf: PerfModel, attribution: Attribution) -> Self {
        Self { perf, attribution }
    }

    /// E(m,n,s) in joules.
    pub fn energy(&self, spec: &SystemSpec, m: u32, n: u32) -> f64 {
        let c = self.perf.query_cost(spec, m, n);
        match self.attribution {
            Attribution::Total => c.energy_j,
            Attribution::Net => c.net_energy_j,
        }
    }

    /// R(m,n,s) in seconds (forwarded for cost-function convenience).
    pub fn runtime(&self, spec: &SystemSpec, m: u32, n: u32) -> f64 {
        self.perf.runtime(spec, m, n)
    }

    /// `(E, R)` from a single model evaluation — the building block of
    /// [`super::cost_table::CostTable`]. Produces exactly the values
    /// [`Self::energy`] and [`Self::runtime`] would (same code path,
    /// same f64 operation order).
    pub fn energy_and_runtime(&self, spec: &SystemSpec, m: u32, n: u32) -> (f64, f64) {
        let c = self.perf.query_cost(spec, m, n);
        let e = match self.attribution {
            Attribution::Total => c.energy_j,
            Attribution::Net => c.net_energy_j,
        };
        (e, c.runtime_s)
    }

    /// Mean energy per *input* token with fixed n — `E_sys,in(m)` of
    /// Eq. 9 (the paper's input-sweep curves use n = 32).
    pub fn energy_per_input_token(&self, spec: &SystemSpec, m: u32, fixed_n: u32) -> f64 {
        self.energy(spec, m, fixed_n) / m.max(1) as f64
    }

    /// Mean energy per *output* token with fixed m — `E_sys,out(n)` of
    /// Eq. 10 (the paper's output-sweep curves use m = 32).
    pub fn energy_per_output_token(&self, spec: &SystemSpec, n: u32, fixed_m: u32) -> f64 {
        self.energy(spec, fixed_m, n) / n.max(1) as f64
    }
}

/// Eq. 9/10 evaluator: total predicted energy of a histogram of token
/// counts split at threshold T between two systems (small → `small_sys`,
/// large → `big_sys`).
///
/// `freqs[t]` = number of queries with token count `t` (the Alpaca
/// histograms of Fig. 3); `energy_at(t, sys)` = mean per-token energy.
pub fn threshold_split_energy<F>(
    freqs: &[(u32, f64)],
    threshold: u32,
    mut energy_per_token_on: F,
) -> f64
where
    F: FnMut(u32, bool) -> f64, // (token_count, use_small_system) -> J/token
{
    let mut total = 0.0;
    for &(t, freq) in freqs {
        let small = t <= threshold;
        total += t as f64 * freq * energy_per_token_on(t, small);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::{system_catalog, SystemId};
    use crate::model::llm_catalog;

    fn em() -> EnergyModel {
        EnergyModel::new(PerfModel::new(llm_catalog()[1].clone()))
    }

    #[test]
    fn net_below_total() {
        let specs = system_catalog();
        let total = em();
        let net = EnergyModel::with_attribution(total.perf.clone(), Attribution::Net);
        for spec in &specs {
            assert!(net.energy(spec, 64, 64) < total.energy(spec, 64, 64), "{}", spec.name);
        }
    }

    #[test]
    fn per_token_metrics_positive_and_finite() {
        let e = em();
        let specs = system_catalog();
        for spec in &specs {
            for t in [8u32, 32, 256, 2048] {
                let ein = e.energy_per_input_token(spec, t, 32);
                assert!(ein.is_finite() && ein > 0.0);
            }
            for t in [8u32, 32, 256] {
                let eout = e.energy_per_output_token(spec, t, 32);
                assert!(eout.is_finite() && eout > 0.0);
            }
        }
    }

    #[test]
    fn input_crossover_exists_near_paper_threshold() {
        // The mechanism behind T_in = 32: M1 cheaper per token at small m,
        // A100 cheaper at large m, crossing in the tens-of-tokens regime.
        let e = em();
        let specs = system_catalog();
        let m1 = &specs[SystemId::M1_PRO.0];
        let a100 = &specs[SystemId::SWING_A100.0];
        let mut crossover = None;
        let mut prev_sign = None;
        for m in 1..=2048u32 {
            let d = e.energy_per_input_token(m1, m, 32) - e.energy_per_input_token(a100, m, 32);
            let sign = d > 0.0;
            if let Some(p) = prev_sign {
                if p != sign {
                    crossover = Some(m);
                    break;
                }
            }
            prev_sign = Some(sign);
        }
        let x = crossover.expect("no M1/A100 crossover in input sweep");
        assert!((8..=128).contains(&x), "crossover at {x}, expected near 32");
    }

    #[test]
    fn output_crossover_exists() {
        let e = em();
        let specs = system_catalog();
        let m1 = &specs[SystemId::M1_PRO.0];
        let a100 = &specs[SystemId::SWING_A100.0];
        // M1 cheaper for very small generations...
        assert!(
            e.energy_per_output_token(m1, 8, 32) < e.energy_per_output_token(a100, 8, 32)
        );
        // ...but worse near its context cliff
        assert!(
            e.energy_per_output_token(m1, 512, 32) > e.energy_per_output_token(a100, 512, 32)
        );
    }

    #[test]
    fn threshold_split_reduces_to_single_system_at_extremes() {
        let freqs: Vec<(u32, f64)> = (1..=100).map(|t| (t, 1.0)).collect();
        let small_only = threshold_split_energy(&freqs, 100, |_, small| {
            assert!(small);
            1.0
        });
        let big_only = threshold_split_energy(&freqs, 0, |_, small| {
            assert!(!small);
            2.0
        });
        let sum_t: f64 = (1..=100).map(|t| t as f64).sum();
        assert!((small_only - sum_t).abs() < 1e-9);
        assert!((big_only - 2.0 * sum_t).abs() < 1e-9);
    }
}
