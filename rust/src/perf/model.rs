//! Runtime model `R(m,n,s)` and the phase decomposition behind it.

use crate::hw::power::{Phase, PowerModel};
use crate::hw::spec::SystemSpec;
use crate::model::LlmSpec;

/// Why a query cannot run on a system (the paper's observed OOMs, §5.3–5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Feasibility {
    Ok,
    /// weights + KV cache exceed VRAM (V100 16 GB cases)
    OutOfMemory,
    /// beyond the system's hard generation limit (M1 > 512 out)
    ContextLimit,
}

/// Cost of one query on one system: the paper's `R` and `E` plus the
/// phase breakdown the measurement simulators sample.
#[derive(Clone, Debug)]
pub struct QueryCost {
    pub runtime_s: f64,
    pub energy_j: f64,
    /// net of the idle floor (RAPL-style attribution, Eq. 7)
    pub net_energy_j: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub overhead_s: f64,
    pub feasibility: Feasibility,
}

impl QueryCost {
    pub fn is_feasible(&self) -> bool {
        self.feasibility == Feasibility::Ok
    }

    /// Joules per token over all processed tokens — the y-axis of
    /// Figs. 1(c)/2(c).
    pub fn energy_per_token(&self, m: u32, n: u32) -> f64 {
        self.energy_j / (m + n).max(1) as f64
    }

    /// Tokens per second over the full query — Figs. 1(b)/2(b).
    pub fn throughput(&self, m: u32, n: u32) -> f64 {
        (m + n).max(1) as f64 / self.runtime_s
    }
}

/// Cost of one *batch* of queries dispatched together on one system —
/// the batched extension of `R`/`E` (Wilkins et al., arXiv 2407.04014).
///
/// Execution model (static batch, the coordinator's `take_batch`
/// semantics): one dispatch overhead for the whole batch, prefill work
/// summed across members, then decode steps that stride at the max-`n`
/// member's pace. Each decode step streams the weights **once** for the
/// whole batch but reads every live member's KV cache and spends every
/// live member's FLOPs; members retire from the live set as their `n`
/// completes. This is where batching pays: the dispatch overhead and the
/// per-step weight traffic amortize over the batch width.
#[derive(Clone, Debug)]
pub struct BatchCost {
    /// wall time from dispatch to the last member's completion
    pub runtime_s: f64,
    pub energy_j: f64,
    /// net of the idle floor (RAPL-style attribution, Eq. 7)
    pub net_energy_j: f64,
    /// Σ member prefill time (batch prefill is serialized compute)
    pub prefill_s: f64,
    /// decode time through the max-n member's last step
    pub decode_s: f64,
    pub overhead_s: f64,
    pub feasibility: Feasibility,
    /// per-member completion offset from batch start, in input order
    /// (overhead + full batch prefill + decode through that member's n)
    pub member_finish_s: Vec<f64>,
}

impl BatchCost {
    pub fn is_feasible(&self) -> bool {
        self.feasibility == Feasibility::Ok
    }
}

/// The paper's per-(model, system) performance model.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub llm: LlmSpec,
    /// hard cap on generated tokens for unified-memory parts (M1: 512)
    pub m1_style_gen_cap: u32,
}

impl PerfModel {
    pub fn new(llm: LlmSpec) -> Self {
        Self { llm, m1_style_gen_cap: 512 }
    }

    /// Feasibility check (paper §5.3/§5.4): VRAM OOM and generation caps.
    pub fn feasibility(&self, spec: &SystemSpec, m: u32, n: u32) -> Feasibility {
        if self.llm.footprint_bytes(m as f64, n as f64) > spec.vram_bytes {
            return Feasibility::OutOfMemory;
        }
        if spec.accel == crate::hw::spec::Accelerator::AppleSilicon {
            // the paper ran no Falcon on the M1 (">2 orders of magnitude
            // greater runtime", §5.1) and observed a hard 512-token
            // generation ceiling (§5.4)
            if self.llm.mps_incompatible {
                return Feasibility::ContextLimit;
            }
            if n > self.m1_style_gen_cap {
                return Feasibility::ContextLimit;
            }
        }
        Feasibility::Ok
    }

    /// Prefill wall time: compute roofline with a bandwidth floor.
    pub fn prefill_time(&self, spec: &SystemSpec, m: u32) -> f64 {
        let m = m as f64;
        let compute = self.llm.prefill_flops(m) / spec.compute_flops;
        // weights must be touched once regardless of m
        let bw_floor = self.llm.weight_bytes() / spec.mem_bw;
        compute.max(bw_floor) * spec.throttle_factor(m)
    }

    /// One decode step at context length `ctx` (bandwidth roofline with a
    /// compute floor + per-step launch cost).
    pub fn decode_step_time(&self, spec: &SystemSpec, ctx: f64) -> f64 {
        let bw = self.llm.decode_bytes(ctx) / spec.mem_bw;
        let compute = self.llm.decode_flops(ctx) / spec.compute_flops;
        bw.max(compute) * spec.throttle_factor(ctx)
    }

    /// Total decode time for n tokens starting from context m. Closed
    /// form is impossible with throttling, so we integrate per token but
    /// in blocks of 16 for speed (error < 1% — verified in tests).
    pub fn decode_time(&self, spec: &SystemSpec, m: u32, n: u32) -> f64 {
        let mut total = 0.0;
        let m = m as f64;
        let n_i = n as u64;
        let block = 16u64;
        let mut i = 0u64;
        while i < n_i {
            let steps = block.min(n_i - i) as f64;
            let mid_ctx = m + i as f64 + steps / 2.0;
            total += self.decode_step_time(spec, mid_ctx) * steps;
            i += block.min(n_i - i);
        }
        total
    }

    /// Full runtime R(m,n,s).
    pub fn runtime(&self, spec: &SystemSpec, m: u32, n: u32) -> f64 {
        spec.overhead_s + self.prefill_time(spec, m) + self.decode_time(spec, m, n)
    }

    /// Phase-resolved power profile for measurement simulation.
    pub fn power_model(&self, spec: &SystemSpec, m: u32, n: u32) -> PowerModel {
        let mut phases = Vec::with_capacity(3);
        if spec.overhead_s > 0.0 {
            // dispatch: host busy, accelerator near idle
            phases.push(Phase { dur_s: spec.overhead_s, util: 0.05, host_active: true });
        }
        let pf = self.prefill_time(spec, m);
        if pf > 0.0 {
            phases.push(Phase { dur_s: pf, util: spec.util_prefill, host_active: true });
        }
        let dc = self.decode_time(spec, m, n);
        if dc > 0.0 {
            phases.push(Phase { dur_s: dc, util: spec.util_decode, host_active: true });
        }
        PowerModel { phases }
    }

    /// The full cost record: R, E (total and net), and the phase split.
    pub fn query_cost(&self, spec: &SystemSpec, m: u32, n: u32) -> QueryCost {
        let feasibility = self.feasibility(spec, m, n);
        let pm = self.power_model(spec, m, n);
        let prefill_s = self.prefill_time(spec, m);
        let decode_s = self.decode_time(spec, m, n);
        QueryCost {
            runtime_s: pm.total_time(),
            energy_j: pm.total_energy(spec),
            net_energy_j: pm.net_energy(spec),
            prefill_s,
            decode_s,
            overhead_s: spec.overhead_s,
            feasibility,
        }
    }

    /// Wall time to decode the absolute step span `[start, end)` with a
    /// **fixed live set**, accumulated onto `onto` — the per-decode-step
    /// cost that iteration-level (continuous) batching schedules by.
    ///
    /// Each live member is `(m, joined)`: its prompt length and the
    /// absolute decode step at which it was admitted, so its context at
    /// step `s` is `m + (s - joined)`. Every step streams the weights
    /// **once** for the whole live set, reads every member's KV cache,
    /// and spends every member's FLOPs; the step is throttled by the
    /// longest live context. Integration runs in the same 16-step blocks
    /// as [`Self::decode_time`].
    ///
    /// Returning `onto + span` (with the blocks added one at a time onto
    /// `onto`) rather than the bare span is what lets callers chain
    /// segments — admissions and retirements at step boundaries — and
    /// land on *bit-identical* totals to one fused loop over the same
    /// segments: float addition is not associative, so summing a segment
    /// locally and then adding it would round differently. With every
    /// member joined at step 0 this is exactly the historical inner loop
    /// of [`Self::batch_cost`] (`mid - 0.0 == mid` bitwise), which is
    /// how the static batch cost becomes the closed-form sum of step
    /// costs over retirement segments — pinned by
    /// `batch_cost_matches_pre_factoring_reference` below.
    pub fn decode_span_time(
        &self,
        spec: &SystemSpec,
        live: &[(u32, u64)],
        start: u64,
        end: u64,
        onto: f64,
    ) -> f64 {
        let mut t = onto;
        let mut i = start;
        while i < end {
            let block = 16u64.min(end - i);
            let mid = i as f64 + block as f64 / 2.0;
            let mut bytes = self.llm.weight_bytes(); // streamed once per step
            let mut flops = 0.0f64;
            let mut max_ctx = 0.0f64;
            for &(m, joined) in live {
                let ctx = m as f64 + (mid - joined as f64);
                bytes += self.llm.kv_bytes_per_token() * self.llm.effective_ctx(ctx);
                flops += self.llm.decode_flops(ctx);
                max_ctx = max_ctx.max(ctx);
            }
            let per_step = (bytes / spec.mem_bw)
                .max(flops / spec.compute_flops)
                * spec.throttle_factor(max_ctx);
            t += per_step * block as f64;
            i += block;
        }
        t
    }

    /// Batch feasibility: every member must pass its per-query checks
    /// (generation caps, MPS compatibility) *and* the summed footprint —
    /// weights once plus every member's KV cache and scratch — must fit
    /// in VRAM. A batch of OOM-compatible singles can still OOM jointly.
    pub fn batch_feasibility(&self, spec: &SystemSpec, members: &[(u32, u32)]) -> Feasibility {
        let mut extra_bytes = 0.0;
        for &(m, n) in members {
            let f = self.feasibility(spec, m, n);
            if f != Feasibility::Ok {
                return f;
            }
            extra_bytes += self.llm.footprint_bytes(m as f64, n as f64) - self.llm.weight_bytes();
        }
        if self.llm.weight_bytes() + extra_bytes > spec.vram_bytes {
            return Feasibility::OutOfMemory;
        }
        Feasibility::Ok
    }

    /// Cost of dispatching `members` (each an `(m, n)` pair) as one
    /// static batch on `spec` — see [`BatchCost`] for the execution
    /// model. A single-member batch takes exactly the
    /// [`Self::query_cost`] code path, so its numbers are bit-identical
    /// to serial evaluation (the `max_batch = 1` equivalence the
    /// simulator's property tests pin).
    pub fn batch_cost(&self, spec: &SystemSpec, members: &[(u32, u32)]) -> BatchCost {
        assert!(!members.is_empty(), "batch_cost needs at least one member");
        if members.len() == 1 {
            let (m, n) = members[0];
            let c = self.query_cost(spec, m, n);
            return BatchCost {
                runtime_s: c.runtime_s,
                energy_j: c.energy_j,
                net_energy_j: c.net_energy_j,
                prefill_s: c.prefill_s,
                decode_s: c.decode_s,
                overhead_s: c.overhead_s,
                feasibility: c.feasibility,
                member_finish_s: vec![c.runtime_s],
            };
        }
        let feasibility = self.batch_feasibility(spec, members);
        if feasibility != Feasibility::Ok {
            return BatchCost {
                runtime_s: f64::NAN,
                energy_j: f64::NAN,
                net_energy_j: f64::NAN,
                prefill_s: f64::NAN,
                decode_s: f64::NAN,
                overhead_s: spec.overhead_s,
                feasibility,
                member_finish_s: vec![f64::NAN; members.len()],
            };
        }

        let prefill_s: f64 = members.iter().map(|&(m, _)| self.prefill_time(spec, m)).sum();

        // Decode: the closed-form sum of per-step span costs
        // ([`Self::decode_span_time`]) over retirement segments. `order`
        // sorts member indices by ascending n; within a segment all
        // members of the live suffix decode together, and every member
        // joined at step 0 (static membership — continuous admission is
        // the engines' business, chaining the same span primitive from
        // nonzero `joined` offsets).
        let mut order: Vec<usize> = (0..members.len()).collect();
        order.sort_by_key(|&i| members[i].1);
        let joined: Vec<(u32, u64)> =
            order.iter().map(|&i| (members[i].0, 0u64)).collect();
        let max_n = members.iter().map(|&(_, n)| n).max().unwrap() as u64;
        let mut decode_done = vec![0.0f64; members.len()];
        let mut t = 0.0f64; // cumulative decode seconds
        let mut step = 0u64; // decode steps completed so far
        let mut retired = 0usize; // members of `order` already finished
        while step < max_n {
            // retire members whose n is exhausted at this step count
            while retired < order.len() && members[order[retired]].1 as u64 <= step {
                decode_done[order[retired]] = t;
                retired += 1;
            }
            let seg_end = members[order[retired]].1 as u64; // > step
            t = self.decode_span_time(spec, &joined[retired..], step, seg_end, t);
            step = seg_end;
        }
        while retired < order.len() {
            decode_done[order[retired]] = t;
            retired += 1;
        }
        let decode_s = t;

        // Energy through the same phase-resolved power model as
        // query_cost: one overhead phase for the whole batch.
        let mut phases = Vec::with_capacity(3);
        if spec.overhead_s > 0.0 {
            phases.push(Phase { dur_s: spec.overhead_s, util: 0.05, host_active: true });
        }
        if prefill_s > 0.0 {
            phases.push(Phase { dur_s: prefill_s, util: spec.util_prefill, host_active: true });
        }
        if decode_s > 0.0 {
            phases.push(Phase { dur_s: decode_s, util: spec.util_decode, host_active: true });
        }
        let pm = PowerModel { phases };
        BatchCost {
            runtime_s: pm.total_time(),
            energy_j: pm.total_energy(spec),
            net_energy_j: pm.net_energy(spec),
            prefill_s,
            decode_s,
            overhead_s: spec.overhead_s,
            feasibility,
            member_finish_s: decode_done
                .iter()
                .map(|&d| spec.overhead_s + prefill_s + d)
                .collect(),
        }
    }

    /// The pre-factoring [`Self::batch_cost`] with its decode loop
    /// inlined, kept verbatim as the **reference implementation** for
    /// the per-step-span factoring above: the property suite pins
    /// `batch_cost` bit-identical to this on every field, so "batch cost
    /// = sum of step-span costs over retirement segments" stays an
    /// executable claim and nothing downstream of `BatchTable` changes
    /// meaning. Not part of the supported API.
    #[doc(hidden)]
    pub fn batch_cost_reference(&self, spec: &SystemSpec, members: &[(u32, u32)]) -> BatchCost {
        assert!(!members.is_empty(), "batch_cost needs at least one member");
        if members.len() == 1 {
            let (m, n) = members[0];
            let c = self.query_cost(spec, m, n);
            return BatchCost {
                runtime_s: c.runtime_s,
                energy_j: c.energy_j,
                net_energy_j: c.net_energy_j,
                prefill_s: c.prefill_s,
                decode_s: c.decode_s,
                overhead_s: c.overhead_s,
                feasibility: c.feasibility,
                member_finish_s: vec![c.runtime_s],
            };
        }
        let feasibility = self.batch_feasibility(spec, members);
        if feasibility != Feasibility::Ok {
            return BatchCost {
                runtime_s: f64::NAN,
                energy_j: f64::NAN,
                net_energy_j: f64::NAN,
                prefill_s: f64::NAN,
                decode_s: f64::NAN,
                overhead_s: spec.overhead_s,
                feasibility,
                member_finish_s: vec![f64::NAN; members.len()],
            };
        }

        let prefill_s: f64 = members.iter().map(|&(m, _)| self.prefill_time(spec, m)).sum();

        let mut order: Vec<usize> = (0..members.len()).collect();
        order.sort_by_key(|&i| members[i].1);
        let max_n = members.iter().map(|&(_, n)| n).max().unwrap() as u64;
        let mut decode_done = vec![0.0f64; members.len()];
        let mut t = 0.0f64;
        let mut step = 0u64;
        let mut retired = 0usize;
        while step < max_n {
            while retired < order.len() && members[order[retired]].1 as u64 <= step {
                decode_done[order[retired]] = t;
                retired += 1;
            }
            let seg_end = members[order[retired]].1 as u64;
            let live = &order[retired..];
            let mut i = step;
            while i < seg_end {
                let block = 16u64.min(seg_end - i);
                let mid = i as f64 + block as f64 / 2.0;
                let mut bytes = self.llm.weight_bytes();
                let mut flops = 0.0f64;
                let mut max_ctx = 0.0f64;
                for &j in live {
                    let ctx = members[j].0 as f64 + mid;
                    bytes += self.llm.kv_bytes_per_token() * self.llm.effective_ctx(ctx);
                    flops += self.llm.decode_flops(ctx);
                    max_ctx = max_ctx.max(ctx);
                }
                let per_step = (bytes / spec.mem_bw)
                    .max(flops / spec.compute_flops)
                    * spec.throttle_factor(max_ctx);
                t += per_step * block as f64;
                i += block;
            }
            step = seg_end;
        }
        while retired < order.len() {
            decode_done[order[retired]] = t;
            retired += 1;
        }
        let decode_s = t;

        let mut phases = Vec::with_capacity(3);
        if spec.overhead_s > 0.0 {
            phases.push(Phase { dur_s: spec.overhead_s, util: 0.05, host_active: true });
        }
        if prefill_s > 0.0 {
            phases.push(Phase { dur_s: prefill_s, util: spec.util_prefill, host_active: true });
        }
        if decode_s > 0.0 {
            phases.push(Phase { dur_s: decode_s, util: spec.util_decode, host_active: true });
        }
        let pm = PowerModel { phases };
        BatchCost {
            runtime_s: pm.total_time(),
            energy_j: pm.total_energy(spec),
            net_energy_j: pm.net_energy(spec),
            prefill_s,
            decode_s,
            overhead_s: spec.overhead_s,
            feasibility,
            member_finish_s: decode_done
                .iter()
                .map(|&d| spec.overhead_s + prefill_s + d)
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::{system_catalog, SystemId};
    use crate::model::llm_catalog;

    fn setup() -> (PerfModel, Vec<SystemSpec>) {
        (PerfModel::new(llm_catalog()[1].clone()), system_catalog())
    }

    #[test]
    fn runtime_monotone_in_m_and_n() {
        let (pm, specs) = setup();
        for spec in &specs {
            let mut last = 0.0;
            for m in [8u32, 32, 128, 512, 2048] {
                let r = pm.runtime(spec, m, 32);
                assert!(r > last, "{}: R not increasing at m={m}", spec.name);
                last = r;
            }
            let mut last = 0.0;
            for n in [8u32, 32, 128, 512] {
                let r = pm.runtime(spec, 32, n);
                assert!(r > last, "{}: R not increasing at n={n}", spec.name);
                last = r;
            }
        }
    }

    #[test]
    fn output_tokens_cost_more_than_input() {
        // §5.5: growing n raises runtime far more than growing m
        let (pm, specs) = setup();
        for spec in &specs {
            let dm = pm.runtime(spec, 512, 32) - pm.runtime(spec, 32, 32);
            let dn = pm.runtime(spec, 32, 512) - pm.runtime(spec, 32, 32);
            assert!(dn > dm, "{}: output growth {dn} <= input growth {dm}", spec.name);
        }
    }

    #[test]
    fn m1_slowest_but_efficient_at_small() {
        let (pm, specs) = setup();
        let m1 = &specs[SystemId::M1_PRO.0];
        let a100 = &specs[SystemId::SWING_A100.0];
        // M1 runtime much larger at big inputs (Fig 1a)
        assert!(pm.runtime(m1, 2048, 32) > 4.0 * pm.runtime(a100, 2048, 32));
        // but M1 energy/token lower at small inputs (Fig 1c crossover)
        let e_m1 = pm.query_cost(m1, 8, 32).energy_per_token(8, 32);
        let e_a100 = pm.query_cost(a100, 8, 32).energy_per_token(8, 32);
        assert!(e_m1 < e_a100, "m1 {e_m1} vs a100 {e_a100}");
        // and higher at large inputs
        let e_m1 = pm.query_cost(m1, 2048, 32).energy_per_token(2048, 32);
        let e_a100 = pm.query_cost(a100, 2048, 32).energy_per_token(2048, 32);
        assert!(e_m1 > e_a100, "m1 {e_m1} vs a100 {e_a100} at 2048");
    }

    #[test]
    fn throughput_roofline_shape() {
        // Fig 1b: throughput rises with m then flattens (A100)
        let (pm, specs) = setup();
        let a100 = &specs[SystemId::SWING_A100.0];
        let t8 = pm.query_cost(a100, 8, 32).throughput(8, 32);
        let t512 = pm.query_cost(a100, 512, 32).throughput(512, 32);
        let t2048 = pm.query_cost(a100, 2048, 32).throughput(2048, 32);
        assert!(t512 > 2.0 * t8, "throughput should rise steeply: {t8} → {t512}");
        // flattening: relative growth 512→2048 much smaller than 8→512
        let g1 = t512 / t8;
        let g2 = t2048 / t512;
        assert!(g2 < g1 / 2.0, "no roofline flattening: {g1} then {g2}");
    }

    #[test]
    fn decode_throughput_declines_with_n() {
        // Fig 2b
        let (pm, specs) = setup();
        for spec in &specs {
            let hi = pm.query_cost(spec, 32, 64).throughput(32, 64);
            let lo = pm.query_cost(spec, 32, 512).throughput(32, 512);
            assert!(lo < hi, "{}: throughput must decline with n", spec.name);
        }
    }

    #[test]
    fn energy_per_token_rises_with_n() {
        // Fig 2c
        let (pm, specs) = setup();
        for spec in &specs {
            let lo = pm.query_cost(spec, 32, 64).energy_per_token(32, 64);
            let hi = pm.query_cost(spec, 32, 512).energy_per_token(32, 512);
            assert!(hi > lo, "{}: E/token must rise with n", spec.name);
        }
    }

    #[test]
    fn v100_oom_rules() {
        // §5.4: Falcon OOM > 1024 out; all models > 2048 out on 16 GB V100
        let specs = system_catalog();
        let v100 = &specs[SystemId::PALMETTO_V100.0];
        let falcon = PerfModel::new(llm_catalog()[0].clone());
        let llama = PerfModel::new(llm_catalog()[1].clone());
        assert_eq!(falcon.feasibility(v100, 32, 512), Feasibility::Ok);
        assert_eq!(llama.feasibility(v100, 32, 1024), Feasibility::Ok);
        assert_eq!(llama.feasibility(v100, 32, 4096), Feasibility::OutOfMemory);
        // A100 40 GB runs everything the paper ran
        let a100 = &specs[SystemId::SWING_A100.0];
        assert_eq!(llama.feasibility(a100, 2048, 32), Feasibility::Ok);
        assert_eq!(llama.feasibility(a100, 32, 4096), Feasibility::Ok);
    }

    #[test]
    fn m1_generation_cap() {
        let specs = system_catalog();
        let m1 = &specs[SystemId::M1_PRO.0];
        let (pm, _) = setup();
        assert_eq!(pm.feasibility(m1, 32, 512), Feasibility::Ok);
        assert_eq!(pm.feasibility(m1, 32, 513), Feasibility::ContextLimit);
    }

    #[test]
    fn blocked_decode_integration_accurate() {
        let (pm, specs) = setup();
        let spec = &specs[SystemId::SWING_A100.0];
        // exact per-token sum vs blocked
        let (m, n) = (32u32, 300u32);
        let exact: f64 = (0..n)
            .map(|i| pm.decode_step_time(spec, m as f64 + i as f64 + 0.5))
            .sum();
        let blocked = pm.decode_time(spec, m, n);
        assert!((exact - blocked).abs() / exact < 0.01, "{exact} vs {blocked}");
    }

    #[test]
    fn cost_components_sum_to_runtime() {
        let (pm, specs) = setup();
        for spec in &specs {
            let c = pm.query_cost(spec, 64, 64);
            let sum = c.overhead_s + c.prefill_s + c.decode_s;
            assert!((c.runtime_s - sum).abs() < 1e-9, "{}", spec.name);
            assert!(c.net_energy_j < c.energy_j);
            assert!(c.net_energy_j > 0.0);
        }
    }

    #[test]
    fn singleton_batch_is_bit_identical_to_query_cost() {
        let (pm, specs) = setup();
        for spec in &specs {
            for &(m, n) in &[(8u32, 8u32), (64, 32), (512, 128)] {
                let q = pm.query_cost(spec, m, n);
                let b = pm.batch_cost(spec, &[(m, n)]);
                assert_eq!(b.runtime_s, q.runtime_s, "{}", spec.name);
                assert_eq!(b.energy_j, q.energy_j, "{}", spec.name);
                assert_eq!(b.net_energy_j, q.net_energy_j, "{}", spec.name);
                assert_eq!(b.prefill_s, q.prefill_s);
                assert_eq!(b.decode_s, q.decode_s);
                assert_eq!(b.feasibility, q.feasibility);
                assert_eq!(b.member_finish_s, vec![q.runtime_s]);
            }
        }
    }

    #[test]
    fn batching_amortizes_dispatch_and_weight_traffic() {
        let (pm, specs) = setup();
        let a100 = &specs[SystemId::SWING_A100.0];
        let members = [(64u32, 64u32); 4];
        let b = pm.batch_cost(a100, &members);
        assert!(b.is_feasible());
        let serial: f64 = members.iter().map(|&(m, n)| pm.query_cost(a100, m, n).runtime_s).sum();
        let serial_e: f64 = members.iter().map(|&(m, n)| pm.query_cost(a100, m, n).energy_j).sum();
        // one dispatch instead of four, weights streamed once per step
        assert!(b.runtime_s < serial, "batched {} vs serial {serial}", b.runtime_s);
        assert!(b.energy_j < serial_e, "batched {} vs serial {serial_e}", b.energy_j);
        // but slower than any single member alone
        assert!(b.runtime_s > pm.query_cost(a100, 64, 64).runtime_s);
    }

    #[test]
    fn member_finishes_ordered_by_n_and_bounded_by_runtime() {
        let (pm, specs) = setup();
        let a100 = &specs[SystemId::SWING_A100.0];
        let members = [(32u32, 8u32), (32, 256), (32, 64)];
        let b = pm.batch_cost(a100, &members);
        assert!(b.is_feasible());
        assert_eq!(b.member_finish_s.len(), 3);
        // shorter generations finish earlier; the longest defines runtime
        assert!(b.member_finish_s[0] < b.member_finish_s[2]);
        assert!(b.member_finish_s[2] < b.member_finish_s[1]);
        assert!((b.member_finish_s[1] - b.runtime_s).abs() < 1e-12);
        // every member waits at least for overhead + batch prefill
        for f in &b.member_finish_s {
            assert!(*f >= b.overhead_s + b.prefill_s - 1e-12);
        }
    }

    #[test]
    fn batch_feasibility_catches_joint_oom() {
        let specs = system_catalog();
        let v100 = &specs[SystemId::PALMETTO_V100.0];
        let llama = PerfModel::new(llm_catalog()[1].clone());
        // each member fits alone on the 16 GB V100...
        assert_eq!(llama.feasibility(v100, 32, 1024), Feasibility::Ok);
        // ...but four KV caches of that size cannot coexist
        let members = [(32u32, 1024u32); 4];
        assert_eq!(llama.batch_feasibility(v100, &members), Feasibility::OutOfMemory);
        let b = llama.batch_cost(v100, &members);
        assert_eq!(b.feasibility, Feasibility::OutOfMemory);
        assert!(b.runtime_s.is_nan());
        // per-member caps still dominate: an M1 batch with a >512-token
        // member is a context-limit failure, not an OOM
        let m1 = &specs[SystemId::M1_PRO.0];
        assert_eq!(
            llama.batch_feasibility(m1, &[(8, 8), (8, 513)]),
            Feasibility::ContextLimit
        );
    }

    #[test]
    fn dispatch_energy_matches_overhead_phase() {
        let (pm, specs) = setup();
        for spec in &specs {
            // query_cost's overhead phase carries exactly this energy:
            // subtracting a zero-overhead clone's energy isolates it
            let mut no_overhead = spec.clone();
            no_overhead.overhead_s = 0.0;
            let with = pm.query_cost(spec, 64, 64);
            let without = pm.query_cost(&no_overhead, 64, 64);
            let phase_j = with.energy_j - without.energy_j;
            assert!(
                (spec.dispatch_energy_j() - phase_j).abs() < 1e-9,
                "{}: {} vs {}",
                spec.name,
                spec.dispatch_energy_j(),
                phase_j
            );
        }
    }

    #[test]
    fn batch_cost_matches_pre_factoring_reference() {
        // the per-step-span factoring must not move a single bit on any
        // field: batch cost IS the sum of span costs over retirement
        // segments
        let (pm, specs) = setup();
        let cases: Vec<Vec<(u32, u32)>> = vec![
            vec![(64, 64)],
            vec![(64, 64); 4],
            vec![(32, 8), (32, 256), (32, 64)],
            vec![(8, 1), (2048, 512), (100, 100), (7, 33), (512, 17)],
            vec![(16, 40), (16, 40), (90, 40), (90, 3)],
            vec![(1, 1), (1, 2), (1, 3), (1, 4), (1, 5), (1, 6), (1, 7)],
        ];
        for spec in &specs {
            for members in &cases {
                let a = pm.batch_cost(spec, members);
                let b = pm.batch_cost_reference(spec, members);
                assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits(), "{}", spec.name);
                assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{}", spec.name);
                assert_eq!(a.net_energy_j.to_bits(), b.net_energy_j.to_bits(), "{}", spec.name);
                assert_eq!(a.prefill_s.to_bits(), b.prefill_s.to_bits());
                assert_eq!(a.decode_s.to_bits(), b.decode_s.to_bits());
                assert_eq!(a.overhead_s.to_bits(), b.overhead_s.to_bits());
                assert_eq!(a.feasibility, b.feasibility);
                assert_eq!(a.member_finish_s.len(), b.member_finish_s.len());
                for (x, y) in a.member_finish_s.iter().zip(&b.member_finish_s) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{}", spec.name);
                }
            }
        }
    }

    #[test]
    fn decode_span_is_join_offset_invariant() {
        // a member admitted at step j decoding [j, j+n) costs exactly
        // what it would from step 0 — contexts depend only on steps
        // decoded since admission (integers stay exact in f64 here)
        let (pm, specs) = setup();
        for spec in &specs {
            for &(m, n, j) in &[(64u32, 120u64, 37u64), (8, 500, 3), (300, 40, 1000)] {
                let from_zero = pm.decode_span_time(spec, &[(m, 0)], 0, n, 0.0);
                let shifted = pm.decode_span_time(spec, &[(m, j)], j, j + n, 0.0);
                assert_eq!(from_zero.to_bits(), shifted.to_bits(), "{}", spec.name);
            }
        }
    }

    #[test]
    fn decode_span_chaining_is_bit_stable() {
        // chaining spans through `onto` equals one fused span over the
        // same live set — the invariant continuous episodes lean on
        let (pm, specs) = setup();
        let spec = &specs[SystemId::SWING_A100.0];
        let live = [(64u32, 0u64), (200, 0), (16, 0)];
        let fused = pm.decode_span_time(spec, &live, 0, 100, 0.0);
        let mut t = 0.0;
        for (a, b) in [(0u64, 13u64), (13, 16), (16, 48), (48, 99), (99, 100)] {
            t = pm.decode_span_time(spec, &live, a, b, t);
        }
        assert_eq!(fused.to_bits(), t.to_bits());
    }

    #[test]
    fn joint_decode_step_cheaper_than_separate_streams() {
        // the continuous-batching payoff at the step level: one merged
        // live set streams the weights once, two separate batches twice
        let (pm, specs) = setup();
        for spec in &specs {
            let joint = pm.decode_span_time(spec, &[(64, 0), (128, 0)], 0, 32, 0.0);
            let a = pm.decode_span_time(spec, &[(64, 0)], 0, 32, 0.0);
            let b = pm.decode_span_time(spec, &[(128, 0)], 0, 32, 0.0);
            assert!(joint < a + b, "{}: {joint} !< {}", spec.name, a + b);
            // and no cheaper than either alone
            assert!(joint > a.max(b), "{}", spec.name);
        }
    }

    #[test]
    fn stored_cache_width_drives_long_ctx_decode() {
        // Mistral's GQA cache (8 heads) streams least; Falcon's
        // HF-2023-stored cache (71 heads) streams most — matching the
        // paper's observation that Falcon degrades/OOMs first.
        let specs = system_catalog();
        let a100 = &specs[SystemId::SWING_A100.0];
        let falcon = PerfModel::new(llm_catalog()[0].clone());
        let llama = PerfModel::new(llm_catalog()[1].clone());
        let mistral = PerfModel::new(llm_catalog()[2].clone());
        let f = falcon.decode_step_time(a100, 4096.0);
        let l = llama.decode_step_time(a100, 4096.0);
        let mi = mistral.decode_step_time(a100, 4096.0);
        assert!(mi < l, "mistral {mi} vs llama {l}");
        assert!(l < f, "llama {l} vs falcon {f}");
    }
}
