//! Runtime model `R(m,n,s)` and the phase decomposition behind it.

use crate::hw::power::{Phase, PowerModel};
use crate::hw::spec::SystemSpec;
use crate::model::LlmSpec;

/// Why a query cannot run on a system (the paper's observed OOMs, §5.3–5.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Feasibility {
    Ok,
    /// weights + KV cache exceed VRAM (V100 16 GB cases)
    OutOfMemory,
    /// beyond the system's hard generation limit (M1 > 512 out)
    ContextLimit,
}

/// Cost of one query on one system: the paper's `R` and `E` plus the
/// phase breakdown the measurement simulators sample.
#[derive(Clone, Debug)]
pub struct QueryCost {
    pub runtime_s: f64,
    pub energy_j: f64,
    /// net of the idle floor (RAPL-style attribution, Eq. 7)
    pub net_energy_j: f64,
    pub prefill_s: f64,
    pub decode_s: f64,
    pub overhead_s: f64,
    pub feasibility: Feasibility,
}

impl QueryCost {
    pub fn is_feasible(&self) -> bool {
        self.feasibility == Feasibility::Ok
    }

    /// Joules per token over all processed tokens — the y-axis of
    /// Figs. 1(c)/2(c).
    pub fn energy_per_token(&self, m: u32, n: u32) -> f64 {
        self.energy_j / (m + n).max(1) as f64
    }

    /// Tokens per second over the full query — Figs. 1(b)/2(b).
    pub fn throughput(&self, m: u32, n: u32) -> f64 {
        (m + n).max(1) as f64 / self.runtime_s
    }
}

/// The paper's per-(model, system) performance model.
#[derive(Clone, Debug)]
pub struct PerfModel {
    pub llm: LlmSpec,
    /// hard cap on generated tokens for unified-memory parts (M1: 512)
    pub m1_style_gen_cap: u32,
}

impl PerfModel {
    pub fn new(llm: LlmSpec) -> Self {
        Self { llm, m1_style_gen_cap: 512 }
    }

    /// Feasibility check (paper §5.3/§5.4): VRAM OOM and generation caps.
    pub fn feasibility(&self, spec: &SystemSpec, m: u32, n: u32) -> Feasibility {
        if self.llm.footprint_bytes(m as f64, n as f64) > spec.vram_bytes {
            return Feasibility::OutOfMemory;
        }
        if spec.accel == crate::hw::spec::Accelerator::AppleSilicon {
            // the paper ran no Falcon on the M1 (">2 orders of magnitude
            // greater runtime", §5.1) and observed a hard 512-token
            // generation ceiling (§5.4)
            if self.llm.mps_incompatible {
                return Feasibility::ContextLimit;
            }
            if n > self.m1_style_gen_cap {
                return Feasibility::ContextLimit;
            }
        }
        Feasibility::Ok
    }

    /// Prefill wall time: compute roofline with a bandwidth floor.
    pub fn prefill_time(&self, spec: &SystemSpec, m: u32) -> f64 {
        let m = m as f64;
        let compute = self.llm.prefill_flops(m) / spec.compute_flops;
        // weights must be touched once regardless of m
        let bw_floor = self.llm.weight_bytes() / spec.mem_bw;
        compute.max(bw_floor) * spec.throttle_factor(m)
    }

    /// One decode step at context length `ctx` (bandwidth roofline with a
    /// compute floor + per-step launch cost).
    pub fn decode_step_time(&self, spec: &SystemSpec, ctx: f64) -> f64 {
        let bw = self.llm.decode_bytes(ctx) / spec.mem_bw;
        let compute = self.llm.decode_flops(ctx) / spec.compute_flops;
        bw.max(compute) * spec.throttle_factor(ctx)
    }

    /// Total decode time for n tokens starting from context m. Closed
    /// form is impossible with throttling, so we integrate per token but
    /// in blocks of 16 for speed (error < 1% — verified in tests).
    pub fn decode_time(&self, spec: &SystemSpec, m: u32, n: u32) -> f64 {
        let mut total = 0.0;
        let m = m as f64;
        let n_i = n as u64;
        let block = 16u64;
        let mut i = 0u64;
        while i < n_i {
            let steps = block.min(n_i - i) as f64;
            let mid_ctx = m + i as f64 + steps / 2.0;
            total += self.decode_step_time(spec, mid_ctx) * steps;
            i += block.min(n_i - i);
        }
        total
    }

    /// Full runtime R(m,n,s).
    pub fn runtime(&self, spec: &SystemSpec, m: u32, n: u32) -> f64 {
        spec.overhead_s + self.prefill_time(spec, m) + self.decode_time(spec, m, n)
    }

    /// Phase-resolved power profile for measurement simulation.
    pub fn power_model(&self, spec: &SystemSpec, m: u32, n: u32) -> PowerModel {
        let mut phases = Vec::with_capacity(3);
        if spec.overhead_s > 0.0 {
            // dispatch: host busy, accelerator near idle
            phases.push(Phase { dur_s: spec.overhead_s, util: 0.05, host_active: true });
        }
        let pf = self.prefill_time(spec, m);
        if pf > 0.0 {
            phases.push(Phase { dur_s: pf, util: spec.util_prefill, host_active: true });
        }
        let dc = self.decode_time(spec, m, n);
        if dc > 0.0 {
            phases.push(Phase { dur_s: dc, util: spec.util_decode, host_active: true });
        }
        PowerModel { phases }
    }

    /// The full cost record: R, E (total and net), and the phase split.
    pub fn query_cost(&self, spec: &SystemSpec, m: u32, n: u32) -> QueryCost {
        let feasibility = self.feasibility(spec, m, n);
        let pm = self.power_model(spec, m, n);
        let prefill_s = self.prefill_time(spec, m);
        let decode_s = self.decode_time(spec, m, n);
        QueryCost {
            runtime_s: pm.total_time(),
            energy_j: pm.total_energy(spec),
            net_energy_j: pm.net_energy(spec),
            prefill_s,
            decode_s,
            overhead_s: spec.overhead_s,
            feasibility,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::{system_catalog, SystemId};
    use crate::model::llm_catalog;

    fn setup() -> (PerfModel, Vec<SystemSpec>) {
        (PerfModel::new(llm_catalog()[1].clone()), system_catalog())
    }

    #[test]
    fn runtime_monotone_in_m_and_n() {
        let (pm, specs) = setup();
        for spec in &specs {
            let mut last = 0.0;
            for m in [8u32, 32, 128, 512, 2048] {
                let r = pm.runtime(spec, m, 32);
                assert!(r > last, "{}: R not increasing at m={m}", spec.name);
                last = r;
            }
            let mut last = 0.0;
            for n in [8u32, 32, 128, 512] {
                let r = pm.runtime(spec, 32, n);
                assert!(r > last, "{}: R not increasing at n={n}", spec.name);
                last = r;
            }
        }
    }

    #[test]
    fn output_tokens_cost_more_than_input() {
        // §5.5: growing n raises runtime far more than growing m
        let (pm, specs) = setup();
        for spec in &specs {
            let dm = pm.runtime(spec, 512, 32) - pm.runtime(spec, 32, 32);
            let dn = pm.runtime(spec, 32, 512) - pm.runtime(spec, 32, 32);
            assert!(dn > dm, "{}: output growth {dn} <= input growth {dm}", spec.name);
        }
    }

    #[test]
    fn m1_slowest_but_efficient_at_small() {
        let (pm, specs) = setup();
        let m1 = &specs[SystemId::M1_PRO.0];
        let a100 = &specs[SystemId::SWING_A100.0];
        // M1 runtime much larger at big inputs (Fig 1a)
        assert!(pm.runtime(m1, 2048, 32) > 4.0 * pm.runtime(a100, 2048, 32));
        // but M1 energy/token lower at small inputs (Fig 1c crossover)
        let e_m1 = pm.query_cost(m1, 8, 32).energy_per_token(8, 32);
        let e_a100 = pm.query_cost(a100, 8, 32).energy_per_token(8, 32);
        assert!(e_m1 < e_a100, "m1 {e_m1} vs a100 {e_a100}");
        // and higher at large inputs
        let e_m1 = pm.query_cost(m1, 2048, 32).energy_per_token(2048, 32);
        let e_a100 = pm.query_cost(a100, 2048, 32).energy_per_token(2048, 32);
        assert!(e_m1 > e_a100, "m1 {e_m1} vs a100 {e_a100} at 2048");
    }

    #[test]
    fn throughput_roofline_shape() {
        // Fig 1b: throughput rises with m then flattens (A100)
        let (pm, specs) = setup();
        let a100 = &specs[SystemId::SWING_A100.0];
        let t8 = pm.query_cost(a100, 8, 32).throughput(8, 32);
        let t512 = pm.query_cost(a100, 512, 32).throughput(512, 32);
        let t2048 = pm.query_cost(a100, 2048, 32).throughput(2048, 32);
        assert!(t512 > 2.0 * t8, "throughput should rise steeply: {t8} → {t512}");
        // flattening: relative growth 512→2048 much smaller than 8→512
        let g1 = t512 / t8;
        let g2 = t2048 / t512;
        assert!(g2 < g1 / 2.0, "no roofline flattening: {g1} then {g2}");
    }

    #[test]
    fn decode_throughput_declines_with_n() {
        // Fig 2b
        let (pm, specs) = setup();
        for spec in &specs {
            let hi = pm.query_cost(spec, 32, 64).throughput(32, 64);
            let lo = pm.query_cost(spec, 32, 512).throughput(32, 512);
            assert!(lo < hi, "{}: throughput must decline with n", spec.name);
        }
    }

    #[test]
    fn energy_per_token_rises_with_n() {
        // Fig 2c
        let (pm, specs) = setup();
        for spec in &specs {
            let lo = pm.query_cost(spec, 32, 64).energy_per_token(32, 64);
            let hi = pm.query_cost(spec, 32, 512).energy_per_token(32, 512);
            assert!(hi > lo, "{}: E/token must rise with n", spec.name);
        }
    }

    #[test]
    fn v100_oom_rules() {
        // §5.4: Falcon OOM > 1024 out; all models > 2048 out on 16 GB V100
        let specs = system_catalog();
        let v100 = &specs[SystemId::PALMETTO_V100.0];
        let falcon = PerfModel::new(llm_catalog()[0].clone());
        let llama = PerfModel::new(llm_catalog()[1].clone());
        assert_eq!(falcon.feasibility(v100, 32, 512), Feasibility::Ok);
        assert_eq!(llama.feasibility(v100, 32, 1024), Feasibility::Ok);
        assert_eq!(llama.feasibility(v100, 32, 4096), Feasibility::OutOfMemory);
        // A100 40 GB runs everything the paper ran
        let a100 = &specs[SystemId::SWING_A100.0];
        assert_eq!(llama.feasibility(a100, 2048, 32), Feasibility::Ok);
        assert_eq!(llama.feasibility(a100, 32, 4096), Feasibility::Ok);
    }

    #[test]
    fn m1_generation_cap() {
        let specs = system_catalog();
        let m1 = &specs[SystemId::M1_PRO.0];
        let (pm, _) = setup();
        assert_eq!(pm.feasibility(m1, 32, 512), Feasibility::Ok);
        assert_eq!(pm.feasibility(m1, 32, 513), Feasibility::ContextLimit);
    }

    #[test]
    fn blocked_decode_integration_accurate() {
        let (pm, specs) = setup();
        let spec = &specs[SystemId::SWING_A100.0];
        // exact per-token sum vs blocked
        let (m, n) = (32u32, 300u32);
        let exact: f64 = (0..n)
            .map(|i| pm.decode_step_time(spec, m as f64 + i as f64 + 0.5))
            .sum();
        let blocked = pm.decode_time(spec, m, n);
        assert!((exact - blocked).abs() / exact < 0.01, "{exact} vs {blocked}");
    }

    #[test]
    fn cost_components_sum_to_runtime() {
        let (pm, specs) = setup();
        for spec in &specs {
            let c = pm.query_cost(spec, 64, 64);
            let sum = c.overhead_s + c.prefill_s + c.decode_s;
            assert!((c.runtime_s - sum).abs() < 1e-9, "{}", spec.name);
            assert!(c.net_energy_j < c.energy_j);
            assert!(c.net_energy_j > 0.0);
        }
    }

    #[test]
    fn stored_cache_width_drives_long_ctx_decode() {
        // Mistral's GQA cache (8 heads) streams least; Falcon's
        // HF-2023-stored cache (71 heads) streams most — matching the
        // paper's observation that Falcon degrades/OOMs first.
        let specs = system_catalog();
        let a100 = &specs[SystemId::SWING_A100.0];
        let falcon = PerfModel::new(llm_catalog()[0].clone());
        let llama = PerfModel::new(llm_catalog()[1].clone());
        let mistral = PerfModel::new(llm_catalog()[2].clone());
        let f = falcon.decode_step_time(a100, 4096.0);
        let l = llama.decode_step_time(a100, 4096.0);
        let mi = mistral.decode_step_time(a100, 4096.0);
        assert!(mi < l, "mistral {mi} vs llama {l}");
        assert!(l < f, "llama {l} vs falcon {f}");
    }
}
