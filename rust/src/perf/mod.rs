//! Performance & energy models: `R(m,n,s)` and `E(m,n,s)` (Eq. 1).
//!
//! This is the quantitative substrate for every figure in the paper. A
//! query's execution decomposes into phases (dispatch overhead → prefill
//! → n decode steps); runtime follows a roofline per phase (prefill
//! compute-bound, decode bandwidth-bound, the §5.5 asymmetry) and energy
//! is the exact integral of the phase-resolved power model.

pub mod calibration;
pub mod cost_table;
pub mod energy;
pub mod model;
pub mod roofline;

pub use cost_table::{BatchTable, CostCell, CostTable};
pub use energy::EnergyModel;
pub use model::{BatchCost, PerfModel, QueryCost, Feasibility};
