//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 emits HloModuleProtos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §1).

use crate::util::error::{Context, Result};
use std::path::Path;

/// Process-wide PJRT client + compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this environment).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text file and compile it to an executable.
    pub fn compile_hlo_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let rt = Runtime::cpu().expect("PJRT CPU client");
        assert!(rt.device_count() >= 1);
        let p = rt.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("host"), "platform={p}");
    }

    #[test]
    fn compile_missing_file_errors() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.compile_hlo_file(Path::new("/nonexistent.hlo.txt")).is_err());
    }
}
