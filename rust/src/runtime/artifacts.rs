//! Artifact loading: `manifest.json` + `weights.bin` + compiled HLO
//! executables, matching `python/compile/aot.py`'s output format exactly.
//!
//! [`Manifest`] parsing/validation is plain std and always available;
//! `ArtifactBundle` uploads weights and compiles HLO through the `xla`
//! crate, so it is gated behind the `pjrt` feature (linking it here
//! would break rustdoc in default builds).

#[cfg(feature = "pjrt")]
use super::client::Runtime;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use crate::{anyhow, bail};
use std::collections::BTreeMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub seed: u64,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub cache_capacity: usize,
    pub prefill_buckets: Vec<usize>,
    pub param_count: u64,
    pub params: Vec<ParamEntry>,
    pub weights_bytes: u64,
    pub entry_files: BTreeMap<String, String>,
}

/// One weight tensor in `weights.bin`.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub elems: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest.json: {e}"))?;
        let cfg = j.req("config").map_err(|e| anyhow!(e))?;
        let get_u = |v: &Json, k: &str| -> Result<u64> {
            v.req(k)
                .map_err(|e| anyhow!(e))?
                .as_u64()
                .ok_or_else(|| anyhow!("manifest: '{k}' not a number"))
        };
        let params_j = j
            .req("weights")
            .and_then(|w| w.req("params"))
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: weights.params not an array"))?;
        let mut params = Vec::with_capacity(params_j.len());
        for p in params_j {
            params.push(ParamEntry {
                name: p
                    .req("name")
                    .map_err(|e| anyhow!(e))?
                    .as_str()
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string(),
                shape: p
                    .req("shape")
                    .map_err(|e| anyhow!(e))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("param shape"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                offset: get_u(p, "offset")? as usize,
                elems: get_u(p, "elems")? as usize,
            });
        }
        let mut entry_files = BTreeMap::new();
        let eps = j
            .req("entrypoints")
            .map_err(|e| anyhow!(e))?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: entrypoints not an object"))?;
        for (name, ep) in eps {
            let file = ep
                .req("file")
                .map_err(|e| anyhow!(e))?
                .as_str()
                .ok_or_else(|| anyhow!("entrypoint file"))?;
            entry_files.insert(name.clone(), file.to_string());
        }
        let buckets = cfg
            .req("prefill_buckets")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("prefill_buckets"))?
            .iter()
            .map(|b| b.as_usize().unwrap_or(0))
            .collect();
        Ok(Manifest {
            seed: get_u(&j, "seed")?,
            vocab: get_u(cfg, "vocab")? as usize,
            d_model: get_u(cfg, "d_model")? as usize,
            n_layers: get_u(cfg, "n_layers")? as usize,
            n_heads: get_u(cfg, "n_heads")? as usize,
            d_head: get_u(cfg, "d_head")? as usize,
            d_ff: get_u(cfg, "d_ff")? as usize,
            cache_capacity: get_u(cfg, "cache_capacity")? as usize,
            prefill_buckets: buckets,
            param_count: get_u(cfg, "param_count")?,
            weights_bytes: j
                .req("weights")
                .and_then(|w| w.req("bytes"))
                .map_err(|e| anyhow!(e))?
                .as_u64()
                .ok_or_else(|| anyhow!("weights.bytes"))?,
            params,
            entry_files,
        })
    }

    /// Smallest bucket that can hold a prompt of `len` tokens.
    pub fn bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().find(|&b| b >= len)
    }

    pub fn validate(&self) -> Result<()> {
        if self.params.is_empty() {
            bail!("manifest has no params");
        }
        let mut offset = 0usize;
        for p in &self.params {
            if p.offset != offset {
                bail!("param {} offset {} != expected {offset}", p.name, p.offset);
            }
            let n: usize = p.shape.iter().product();
            if n != p.elems {
                bail!("param {} shape/elems mismatch", p.name);
            }
            offset += p.elems * 4;
        }
        if offset as u64 != self.weights_bytes {
            bail!("weights.bytes {} != sum of params {offset}", self.weights_bytes);
        }
        let mut sorted = self.prefill_buckets.clone();
        sorted.sort_unstable();
        if sorted != self.prefill_buckets || sorted.is_empty() {
            bail!("prefill_buckets must be ascending and non-empty");
        }
        if *sorted.last().unwrap() > self.cache_capacity {
            bail!("largest bucket exceeds cache capacity");
        }
        Ok(())
    }
}

/// Weights (resident on the PJRT device) + compiled executables.
#[cfg(feature = "pjrt")]
pub struct ArtifactBundle {
    pub manifest: Manifest,
    /// weights uploaded once at load time (§Perf: no per-call transfer)
    pub weight_bufs: Vec<xla::PjRtBuffer>,
    /// prefill executables keyed by bucket size
    pub prefill: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    pub decode: xla::PjRtLoadedExecutable,
    /// device-side slicer: packed state -> logits (the only per-step
    /// host transfer)
    pub logits: xla::PjRtLoadedExecutable,
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl ArtifactBundle {
    /// Load manifest + weights (uploaded to the device once) and compile
    /// every entrypoint.
    pub fn load(rt: &Runtime, dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.validate()?;

        let raw = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading {}/weights.bin", dir.display()))?;
        if raw.len() as u64 != manifest.weights_bytes {
            bail!("weights.bin size {} != manifest {}", raw.len(), manifest.weights_bytes);
        }
        let client = rt.client().clone();
        let mut weight_bufs = Vec::with_capacity(manifest.params.len());
        for p in &manifest.params {
            let bytes = &raw[p.offset..p.offset + p.elems * 4];
            // decode f32 LE explicitly (alignment-safe); NB: the crate's
            // `buffer_from_host_raw_bytes` mixes up ElementType and
            // PrimitiveType discriminants, so use the typed upload.
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = client
                .buffer_from_host_buffer::<f32>(&floats, &p.shape, None)
                .with_context(|| format!("uploading {}", p.name))?;
            weight_bufs.push(buf);
        }

        let mut prefill = BTreeMap::new();
        let mut decode = None;
        let mut logits = None;
        for (name, file) in &manifest.entry_files {
            let exe = rt.compile_hlo_file(&dir.join(file))?;
            if let Some(s) = name.strip_prefix("prefill_s") {
                prefill.insert(s.parse::<usize>().context("bucket name")?, exe);
            } else if name == "decode" {
                decode = Some(exe);
            } else if name == "logits" {
                logits = Some(exe);
            }
        }
        let decode = decode.ok_or_else(|| anyhow!("manifest has no decode entrypoint"))?;
        let logits = logits
            .ok_or_else(|| anyhow!("manifest has no logits entrypoint — regenerate with `make artifacts` (v2)"))?;
        if prefill.is_empty() {
            bail!("manifest has no prefill entrypoints");
        }
        Ok(Self { manifest, weight_bufs, prefill, decode, logits, client, dir: dir.to_path_buf() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "version": 1, "seed": 7,
      "config": {"vocab": 256, "d_model": 8, "n_layers": 1, "n_heads": 2,
                 "d_head": 4, "d_ff": 16, "cache_capacity": 32,
                 "prefill_buckets": [8, 16], "param_count": 100},
      "weights": {"file": "weights.bin", "bytes": 48,
        "params": [
          {"name": "a", "shape": [2, 3], "offset": 0, "elems": 6},
          {"name": "b", "shape": [6], "offset": 24, "elems": 6}]},
      "entrypoints": {"prefill_s8": {"file": "prefill_s8.hlo.txt"},
                      "decode": {"file": "decode.hlo.txt"}}
    }"#;

    #[test]
    fn parse_and_validate() {
        let m = Manifest::parse(MINI).unwrap();
        m.validate().unwrap();
        assert_eq!(m.vocab, 256);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.entry_files["decode"], "decode.hlo.txt");
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(MINI).unwrap();
        assert_eq!(m.bucket_for(1), Some(8));
        assert_eq!(m.bucket_for(8), Some(8));
        assert_eq!(m.bucket_for(9), Some(16));
        assert_eq!(m.bucket_for(17), None);
    }

    #[test]
    fn validate_rejects_bad_offsets() {
        let bad = MINI.replace("\"offset\": 24", "\"offset\": 20");
        assert!(Manifest::parse(&bad).unwrap().validate().is_err());
    }

    #[test]
    fn validate_rejects_size_mismatch() {
        let bad = MINI.replace("\"bytes\": 48", "\"bytes\": 44");
        assert!(Manifest::parse(&bad).unwrap().validate().is_err());
    }

    #[test]
    fn parses_shipped_manifest_if_present() {
        let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            m.validate().unwrap();
            assert_eq!(m.vocab, 256);
            assert!(m.prefill_buckets.contains(&32));
        }
    }
}
