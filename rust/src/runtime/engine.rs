//! The inference engine: bucketed prefill + autoregressive decode over
//! the AOT artifacts. This is the L1/L2 compute path the L3 coordinator
//! drives — pure rust + PJRT at request time.
//!
//! Bucketing trick: prompts are right-padded to the bucket size, and the
//! first "real" step is a decode at `pos = len-1` re-feeding the last
//! prompt token. The decode writes that token's K/V (identical to what
//! prefill computed) and masks every cache row ≥ `pos+1`, so pad garbage
//! is never attended to and the logits are exact for any prompt length —
//! no per-length HLO needed beyond the bucket set.

#[cfg(feature = "pjrt")]
use super::artifacts::ArtifactBundle;
use crate::util::rng::Xoshiro256;
#[cfg(feature = "pjrt")]
use crate::util::error::{Context, Result};
#[cfg(feature = "pjrt")]
use crate::bail;
#[cfg(feature = "pjrt")]
use std::time::Instant;

/// Sampling configuration for generation.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0.0 = greedy argmax
    pub temperature: f32,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        Self { temperature: 0.0, seed: 0 }
    }
}

/// Outcome of one generation call, with phase timings for the energy
/// accountant.
#[derive(Clone, Debug)]
pub struct GenerationResult {
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    pub bucket: usize,
    pub prefill_s: f64,
    pub decode_s: f64,
}

impl GenerationResult {
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.tokens.len() as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// Engine over one artifact bundle. `generate` is `&self` and the xla
/// executables are internally synchronized, so one engine can be shared
/// behind an `Arc` by worker threads.
#[cfg(feature = "pjrt")]
pub struct InferenceEngine {
    bundle: ArtifactBundle,
}

#[cfg(feature = "pjrt")]
impl InferenceEngine {
    pub fn new(bundle: ArtifactBundle) -> Self {
        Self { bundle }
    }

    pub fn manifest(&self) -> &super::artifacts::Manifest {
        &self.bundle.manifest
    }

    /// Generate up to `gen_tokens` tokens after `prompt` (token ids incl.
    /// BOS). Stops early only at cache capacity.
    // Sanctioned wall-clock: times real PJRT device execution (see
    // clippy.toml `disallowed-methods`).
    #[allow(clippy::disallowed_methods)]
    pub fn generate(&self, prompt: &[i32], gen_tokens: u32, sp: SamplingParams) -> Result<GenerationResult> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let man = &self.bundle.manifest;
        // truncate from the front if the prompt exceeds the largest bucket
        let max_bucket = *man.prefill_buckets.last().unwrap();
        let prompt = if prompt.len() > max_bucket {
            &prompt[prompt.len() - max_bucket..]
        } else {
            prompt
        };
        let len = prompt.len();
        let bucket = man.bucket_for(len).context("no bucket fits prompt")?;

        // ---- prefill (padded to bucket) ----
        // §Perf path: weights are device-resident buffers uploaded at
        // load; outputs are untupled (aot.py return_tuple=False), so the
        // KV caches stay on device and chain into decode via execute_b —
        // only logits (1 KB) cross back to the host per step.
        let t0 = Instant::now();
        let mut padded: Vec<i32> = prompt.to_vec();
        padded.resize(bucket, super::tokenizer::BOS);
        let client = &self.bundle.client;
        let tok_buf = client.buffer_from_host_buffer::<i32>(&padded, &[bucket], None)?;
        let exe = &self.bundle.prefill[&bucket];
        let mut args: Vec<&xla::PjRtBuffer> = self.bundle.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let mut out = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let mut outputs = out.remove(0);
        if outputs.len() != 1 {
            bail!(
                "prefill returned {} outputs, expected 1 packed state — \
                 regenerate artifacts with `make artifacts` (packed v2 format)",
                outputs.len()
            );
        }
        // packed state [logits | k | v] stays on device across the run
        let mut packed = outputs.pop().unwrap();
        let prefill_s = t0.elapsed().as_secs_f64();

        // ---- decode loop (device-buffer chained) ----
        let t1 = Instant::now();
        let mut rng = Xoshiro256::seed_from(sp.seed);
        let mut pos = (len - 1) as i32;
        let mut token = prompt[len - 1];
        let mut generated = Vec::with_capacity(gen_tokens as usize);
        let cap = man.cache_capacity as i32;
        for _ in 0..gen_tokens {
            if pos + 1 >= cap {
                break; // KV cache full
            }
            let pos_buf = client.buffer_from_host_buffer::<i32>(&[pos], &[1], None)?;
            let tok_buf = client.buffer_from_host_buffer::<i32>(&[token], &[1], None)?;
            let mut args: Vec<&xla::PjRtBuffer> = self.bundle.weight_bufs.iter().collect();
            args.push(&packed);
            args.push(&pos_buf);
            args.push(&tok_buf);
            let mut out = self.bundle.decode.execute_b::<&xla::PjRtBuffer>(&args)?;
            let mut outputs = out.remove(0);
            if outputs.len() != 1 {
                bail!("decode returned {} outputs, expected 1", outputs.len());
            }
            packed = outputs.pop().unwrap();
            // device-side slice: only the vocab-sized logits cross back
            let mut lg_out = self.bundle.logits.execute_b::<&xla::PjRtBuffer>(&[&packed])?;
            let logits: Vec<f32> = lg_out.remove(0).pop().unwrap().to_literal_sync()?.to_vec()?;
            token = sample(&logits, sp.temperature, &mut rng);
            generated.push(token);
            pos += 1;
        }
        let decode_s = t1.elapsed().as_secs_f64();

        Ok(GenerationResult { prompt_len: len, tokens: generated, bucket, prefill_s, decode_s })
    }
}

/// Argmax or temperature sampling over raw logits (only the PJRT engine
/// samples from real logits; kept compiled for its unit tests).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn sample(logits: &[f32], temperature: f32, rng: &mut Xoshiro256) -> i32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    // softmax with temperature, numerically stable
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) / temperature) as f64).exp())
        .collect();
    rng.categorical(&weights) as i32
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_and_sampling() {
        let logits = vec![0.0f32, 5.0, -1.0, 4.9];
        assert_eq!(argmax(&logits), 1);
        let mut rng = Xoshiro256::seed_from(1);
        // greedy
        assert_eq!(sample(&logits, 0.0, &mut rng), 1);
        // low temperature ≈ greedy
        let picks: Vec<i32> = (0..50).map(|_| sample(&logits, 0.01, &mut rng)).collect();
        assert!(picks.iter().filter(|&&p| p == 1).count() > 45);
        // high temperature spreads
        let picks: Vec<i32> = (0..500).map(|_| sample(&logits, 50.0, &mut rng)).collect();
        let distinct: std::collections::BTreeSet<i32> = picks.into_iter().collect();
        assert!(distinct.len() >= 3);
    }

    #[test]
    fn sampling_deterministic_per_seed() {
        let logits: Vec<f32> = (0..64).map(|i| ((i * 37) % 11) as f32 * 0.3).collect();
        let run = |seed| {
            let mut rng = Xoshiro256::seed_from(seed);
            (0..20).map(|_| sample(&logits, 1.0, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
        assert_ne!(run(9), run(10));
    }
}
