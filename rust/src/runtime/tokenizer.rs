//! Byte-level tokenizer: token == byte, with byte 0 reserved as BOS/pad.
//! No external vocab files — any UTF-8 (or binary) text is servable,
//! which keeps the end-to-end example self-contained.

/// Byte-level tokenizer for the served tiny model.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

/// Reserved token: beginning-of-sequence / padding.
pub const BOS: i32 = 0;

impl ByteTokenizer {
    /// Encode text → BOS + bytes (0 bytes are mapped to 1 to keep BOS
    /// unambiguous; lossy only for NUL, which never appears in text).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        out.push(BOS);
        out.extend(text.bytes().map(|b| if b == 0 { 1 } else { b as i32 }));
        out
    }

    /// Decode generated token ids back to (lossy) text.
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t != BOS)
            .map(|&t| (t.clamp(0, 255)) as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        256
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_ascii() {
        let t = ByteTokenizer;
        let ids = t.encode("hello");
        assert_eq!(ids[0], BOS);
        assert_eq!(ids.len(), 6);
        assert_eq!(t.decode(&ids), "hello");
    }

    #[test]
    fn round_trip_utf8() {
        let t = ByteTokenizer;
        let s = "héllo ∞";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn nul_byte_remapped() {
        let t = ByteTokenizer;
        let ids = t.encode("\0");
        assert_eq!(ids, vec![BOS, 1]);
    }

    #[test]
    fn ids_in_vocab_range() {
        let t = ByteTokenizer;
        for id in t.encode("any text at all — ünïcode too") {
            assert!((0..256).contains(&id));
        }
    }
}
