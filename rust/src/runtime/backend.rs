//! Pluggable inference backends for the serving coordinator.
//!
//! Workers drive a [`InferenceBackend`]; the real PJRT engine implements
//! it behind the `pjrt` feature, and [`SimBackend`] implements it
//! unconditionally so the full router/batcher/worker topology runs in
//! any environment — tokens are synthetic but deterministic, and phase
//! timings come from the paper's perf model for the worker's system.

use super::engine::{GenerationResult, SamplingParams};
use crate::hw::spec::SystemSpec;
use crate::perf::model::PerfModel;
use crate::util::error::Result;
use crate::util::rng::Xoshiro256;

/// One generation call — what a worker needs from any engine.
pub trait InferenceBackend: Send {
    fn generate(&self, prompt: &[i32], gen_tokens: u32, sp: SamplingParams)
        -> Result<GenerationResult>;
}

#[cfg(feature = "pjrt")]
impl InferenceBackend for super::engine::InferenceEngine {
    fn generate(
        &self,
        prompt: &[i32],
        gen_tokens: u32,
        sp: SamplingParams,
    ) -> Result<GenerationResult> {
        super::engine::InferenceEngine::generate(self, prompt, gen_tokens, sp)
    }
}

/// Model-driven backend: byte tokens derived deterministically from the
/// (seed, prompt) pair, phase times from `R(m,n,s)`'s decomposition.
///
/// By default generation returns instantly — reported `prefill_s` /
/// `decode_s` are *modeled*, so wall-clock latency through the server
/// reflects dispatch overhead only. Set a non-zero [`time_scale`]
/// (e.g. 0.01 = 100× faster than modeled) to make workers actually
/// occupy the modeled time, which exercises queueing and batching.
///
/// [`time_scale`]: SimBackend::with_time_scale
pub struct SimBackend {
    spec: SystemSpec,
    perf: PerfModel,
    time_scale: f64,
}

impl SimBackend {
    pub fn new(spec: SystemSpec, perf: PerfModel) -> Self {
        Self { spec, perf, time_scale: 0.0 }
    }

    /// Sleep `modeled_time × scale` inside `generate` (0 = no sleep).
    pub fn with_time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale.max(0.0);
        self
    }
}

impl InferenceBackend for SimBackend {
    fn generate(
        &self,
        prompt: &[i32],
        gen_tokens: u32,
        sp: SamplingParams,
    ) -> Result<GenerationResult> {
        let m = prompt.len().max(1) as u32;
        // pure phase durations, matching GenerationResult's contract;
        // dispatch overhead is deliberately excluded — the worker's
        // energy attribution treats dispatch as amortized by batching
        // (it charges attribute(spec, 0.0, prefill, decode))
        let prefill_s = self.perf.prefill_time(&self.spec, m);
        let decode_s = self.perf.decode_time(&self.spec, m, gen_tokens);
        // FNV-1a over the prompt so identical (seed, prompt) pairs
        // reproduce and different prompts diverge
        let mut h = 0xcbf29ce484222325u64;
        for &t in prompt {
            h = (h ^ t as u64).wrapping_mul(0x100000001b3);
        }
        let mut rng = Xoshiro256::seed_from(sp.seed ^ h);
        let tokens: Vec<i32> = (0..gen_tokens).map(|_| rng.below(256) as i32).collect();
        if self.time_scale > 0.0 {
            let dur = (prefill_s + decode_s) * self.time_scale;
            if dur > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dur));
            }
        }
        Ok(GenerationResult { prompt_len: prompt.len(), tokens, bucket: 0, prefill_s, decode_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog::system_catalog;
    use crate::model::llm_catalog;

    fn backend(system: usize) -> SimBackend {
        SimBackend::new(
            system_catalog()[system].clone(),
            PerfModel::new(llm_catalog()[1].clone()),
        )
    }

    #[test]
    fn deterministic_per_seed_and_prompt() {
        let b = backend(1);
        let sp = SamplingParams { temperature: 0.0, seed: 9 };
        let a = b.generate(&[0, 5, 7], 16, sp).unwrap();
        let a2 = b.generate(&[0, 5, 7], 16, sp).unwrap();
        assert_eq!(a.tokens, a2.tokens);
        assert_eq!(a.tokens.len(), 16);
        assert!(a.tokens.iter().all(|&t| (0..256).contains(&t)));
        let other_prompt = b.generate(&[0, 5, 8], 16, sp).unwrap();
        assert_ne!(a.tokens, other_prompt.tokens);
        let other_seed =
            b.generate(&[0, 5, 7], 16, SamplingParams { temperature: 0.0, seed: 10 }).unwrap();
        assert_ne!(a.tokens, other_seed.tokens);
    }

    #[test]
    fn phase_times_follow_the_perf_model() {
        let b = backend(0); // M1
        let sp = SamplingParams::default();
        let short = b.generate(&[0; 8], 8, sp).unwrap();
        let long = b.generate(&[0; 64], 64, sp).unwrap();
        assert!(short.prefill_s > 0.0 && short.decode_s > 0.0);
        assert!(long.prefill_s > short.prefill_s);
        assert!(long.decode_s > short.decode_s);
    }
}
