//! The PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client —
//! the request path never touches Python.

pub mod artifacts;
pub mod client;
pub mod engine;
pub mod tokenizer;

pub use artifacts::{ArtifactBundle, Manifest};
pub use client::Runtime;
pub use engine::{GenerationResult, InferenceEngine, SamplingParams};
pub use tokenizer::ByteTokenizer;
