//! The inference runtime. Two backends sit behind
//! [`backend::InferenceBackend`]:
//!
//! - **PJRT** (`--features pjrt`): loads `artifacts/*.hlo.txt`
//!   (AOT-lowered by `python/compile/aot.py`) and executes them on the
//!   CPU PJRT client — the request path never touches Python. Gated
//!   because it needs the external `xla` crate, which the offline crate
//!   set cannot provide.
//! - **Sim** (always available): [`backend::SimBackend`] serves
//!   deterministic synthetic tokens with phase timings from the paper's
//!   perf model, so the full coordinator topology runs (and is tested)
//!   without artifacts or PJRT.

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod engine;
pub mod tokenizer;

#[cfg(feature = "pjrt")]
pub use artifacts::ArtifactBundle;
pub use artifacts::Manifest;
pub use backend::{InferenceBackend, SimBackend};
#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use engine::InferenceEngine;
pub use engine::{GenerationResult, SamplingParams};
pub use tokenizer::ByteTokenizer;
