//! §Perf bench: the real PJRT inference engine (L1/L2 artifacts driven
//! from rust). Reports prefill latency per bucket and decode tokens/s —
//! the numbers EXPERIMENTS.md §Perf tracks across optimization rounds.
//! Requires `--features pjrt` plus `make artifacts`; self-skips
//! otherwise.

#[cfg(feature = "pjrt")]
use hetsched::runtime::artifacts::ArtifactBundle;
#[cfg(feature = "pjrt")]
use hetsched::runtime::client::Runtime;
#[cfg(feature = "pjrt")]
use hetsched::runtime::engine::{InferenceEngine, SamplingParams};
#[cfg(feature = "pjrt")]
use hetsched::util::benchkit::{bench_header, black_box, Bench};
#[cfg(feature = "pjrt")]
use hetsched::util::tablefmt::fmt_secs;
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(not(feature = "pjrt"))]
fn main() {
    println!("perf_engine needs the real PJRT runtime — rerun with --features pjrt");
}

#[cfg(feature = "pjrt")]
fn main() {
    bench_header("§Perf — PJRT inference engine (real artifacts)");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts missing — run `make artifacts` first (skipping)");
        return;
    }
    let rt = Runtime::cpu().expect("pjrt client");
    let t0 = std::time::Instant::now();
    let bundle = ArtifactBundle::load(&rt, &dir).expect("bundle");
    println!("bundle load+compile: {}", fmt_secs(t0.elapsed().as_secs_f64()));
    let engine = InferenceEngine::new(bundle);
    let buckets = engine.manifest().prefill_buckets.clone();

    let bench = Bench { warmup: 1, min_samples: 5, max_samples: 15, rel_ci_target: 0.05, budget_s: 20.0 };

    // prefill latency per bucket
    for &b in &buckets {
        let prompt: Vec<i32> = (0..b as i32).map(|i| (i % 250) + 1).collect();
        let r = bench.run(&format!("prefill bucket {b}"), b as u64, || {
            black_box(engine.generate(&prompt, 0, SamplingParams::default()).unwrap());
        });
        println!("{}", r.line());
    }

    // decode throughput at small and large contexts
    for (label, prompt_len, gen) in [("decode (short ctx)", 8usize, 64u32), ("decode (long ctx)", 256, 64)] {
        let prompt: Vec<i32> = (0..prompt_len as i32).map(|i| (i % 250) + 1).collect();
        let r = bench.run(label, gen as u64, || {
            black_box(engine.generate(&prompt, gen, SamplingParams::default()).unwrap());
        });
        println!("{}  ({:.1} tok/s)", r.line(), r.throughput());
    }

    println!("\n(structure targets for L1 live in perf::roofline tests: VMEM fit + MXU estimate)");
}
