//! Bench: regenerate **Table 1** (system configurations) from the
//! catalog the experiments actually use, and time catalog construction.

use hetsched::experiments::table1;
use hetsched::hw::catalog::{extended_catalog, system_catalog};
use hetsched::util::benchkit::{bench_header, black_box, Bench};

fn main() {
    bench_header("Table 1 — system configurations");
    println!("{}", table1(&system_catalog()).ascii());
    println!("extension systems (not in the paper):");
    println!("{}", table1(&extended_catalog()[3..]).ascii());

    for s in extended_catalog() {
        s.validate().expect("catalog spec invalid");
    }
    println!("all specs validate ✓");

    let r = Bench::quick().run("system_catalog()", 1, || {
        black_box(system_catalog());
    });
    println!("{}", r.line());
}
