//! Bench (extension): the **λ trade-off of Eq. 1**. The paper defines
//! U = λE + (1−λ)R but evaluates only the energy end; this sweeps λ to
//! expose the full energy/runtime Pareto frontier and checks Eqs. 2–4's
//! partition properties.

use hetsched::hw::catalog::system_catalog;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::oracle::oracle_assign;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Table};
use hetsched::workload::alpaca::AlpacaModel;

fn main() {
    bench_header("λ trade-off — Eq. 1 Pareto frontier (extension)");
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries = AlpacaModel::default().trace(2024, 20_000);

    let lambdas = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
    let mut frontier = Vec::new();
    let mut t = Table::new(&["λ", "energy", "Σ runtime", "→M1", "→A100", "→V100"]);
    for &l in &lambdas {
        let (assign, _) = oracle_assign(&queries, &systems, &energy, l);
        let mut e = 0.0;
        let mut r = 0.0;
        let mut counts = [0u64; 3];
        for (q, sid) in queries.iter().zip(&assign) {
            e += energy.energy(&systems[sid.0], q.input_tokens, q.output_tokens);
            r += energy.runtime(&systems[sid.0], q.input_tokens, q.output_tokens);
            counts[sid.0] += 1;
        }
        frontier.push((l, e, r));
        t.row(&[
            format!("{l:.2}"),
            fmt_joules(e),
            fmt_secs(r),
            counts[0].to_string(),
            counts[1].to_string(),
            counts[2].to_string(),
        ]);
    }
    print!("{}", t.ascii());

    // Pareto structure: energy non-increasing in λ, runtime non-decreasing
    for w in frontier.windows(2) {
        assert!(w[1].1 <= w[0].1 * 1.0001, "energy must fall as λ→1");
        assert!(w[1].2 >= w[0].2 * 0.9999, "runtime must rise as λ→1");
    }
    let span_e = 1.0 - frontier.last().unwrap().1 / frontier[0].1;
    let span_r = frontier.last().unwrap().2 / frontier[0].2 - 1.0;
    println!("\nfrontier span: {:.1}% energy for {:+.0}% runtime between λ=0 and λ=1", span_e * 100.0, span_r * 100.0);
    println!("Pareto monotonicity ✓");

    let b = Bench::quick().run("oracle assignment (20K queries)", queries.len() as u64, || {
        black_box(oracle_assign(&queries, &systems, &energy, 0.5));
    });
    println!("{}", b.line());
}
