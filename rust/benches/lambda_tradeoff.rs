//! Bench (extension): the **λ trade-off of Eq. 1**. The paper defines
//! U = λE + (1−λ)R but evaluates only the energy end; this sweeps λ to
//! expose the full energy/runtime Pareto frontier and checks Eqs. 2–4's
//! partition properties. The grid runs through the parallel sweep
//! executor (`experiments::runner::lambda_sweep`): the model is
//! evaluated once into a CostTable, then every λ point is a cheap
//! argmin pass fanned across cores.

use hetsched::experiments::runner::lambda_sweep;
use hetsched::hw::catalog::system_catalog;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::oracle::oracle_assign;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Table};
use hetsched::workload::alpaca::AlpacaModel;

fn main() {
    bench_header("λ trade-off — Eq. 1 Pareto frontier (extension)");
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries = AlpacaModel::default().trace(2024, 20_000);

    let lambdas = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0];
    let points = lambda_sweep(&queries, &systems, &energy, &lambdas);
    let mut t = Table::new(&["λ", "energy", "Σ runtime", "→M1", "→A100", "→V100"]);
    for p in &points {
        t.row(&[
            format!("{:.2}", p.lambda),
            fmt_joules(p.energy_j),
            fmt_secs(p.runtime_s),
            p.routing[0].to_string(),
            p.routing[1].to_string(),
            p.routing[2].to_string(),
        ]);
    }
    print!("{}", t.ascii());

    // Pareto structure: energy non-increasing in λ, runtime non-decreasing
    for w in points.windows(2) {
        assert!(w[1].energy_j <= w[0].energy_j * 1.0001, "energy must fall as λ→1");
        assert!(w[1].runtime_s >= w[0].runtime_s * 0.9999, "runtime must rise as λ→1");
    }
    let span_e = 1.0 - points.last().unwrap().energy_j / points[0].energy_j;
    let span_r = points.last().unwrap().runtime_s / points[0].runtime_s - 1.0;
    println!("\nfrontier span: {:.1}% energy for {:+.0}% runtime between λ=0 and λ=1", span_e * 100.0, span_r * 100.0);
    println!("Pareto monotonicity ✓");

    // the table-backed sweep must agree with the direct oracle
    let (assign, _) = oracle_assign(&queries, &systems, &energy, 0.5);
    let mid = points.iter().find(|p| p.lambda == 0.5).unwrap();
    assert_eq!(mid.assignment, assign, "λ-sweep diverged from oracle_assign");
    println!("oracle agreement at λ=0.5 ✓");

    let b = Bench::quick().run("λ sweep (8 grid points × 20K queries)", (queries.len() * lambdas.len()) as u64, || {
        black_box(lambda_sweep(&queries, &systems, &energy, &lambdas));
    });
    println!("{}", b.line());

    let b2 = Bench::quick().run("oracle assignment, direct (20K)", queries.len() as u64, || {
        black_box(oracle_assign(&queries, &systems, &energy, 0.5));
    });
    println!("{}", b2.line());
}
