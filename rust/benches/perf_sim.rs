//! §Perf bench: the discrete-event simulation engine. Target (DESIGN.md
//! §7): ≥5 M query-events/s so 52K-query × 64-threshold studies run in
//! seconds — plus the threshold-sweep evaluator throughput.

use hetsched::config::schema::PolicyConfig;
use hetsched::experiments::sweeps::{input_thresholds, threshold_sweep};
use hetsched::hw::catalog::{system_catalog, SystemId};
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::policy::build_policy;
use hetsched::sim::engine::{simulate, SimOptions};
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::workload::alpaca::AlpacaModel;
use hetsched::workload::Query;

fn main() {
    bench_header("§Perf — simulation engine (query-events/s)");
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries = AlpacaModel::default().trace(9, 100_000);

    let bench = Bench::default();
    let cfg = PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() };
    let r = bench.run("simulate 100K Alpaca queries", queries.len() as u64, || {
        let mut p = build_policy(&cfg, energy.clone(), &systems);
        black_box(simulate(&queries, &systems, p.as_mut(), &energy, &SimOptions::default()));
    });
    println!("{}", r.line());
    let qps = r.throughput();
    println!("simulation rate: {qps:.0} queries/s");

    // threshold-sweep evaluator (the Fig 4/5 inner loop)
    let q_in: Vec<Query> = queries.iter().take(52_002).map(|q| Query::new(q.id, q.input_tokens, 32)).collect();
    let grid = input_thresholds();
    let m1 = systems[SystemId::M1_PRO.0].clone();
    let a100 = systems[SystemId::SWING_A100.0].clone();
    let r2 = bench.run(
        "threshold sweep 52K × 16",
        (q_in.len() * grid.len()) as u64,
        || {
            black_box(threshold_sweep(&q_in, &energy, &m1, &a100, &grid, true));
        },
    );
    println!("{}", r2.line());

    let evals = r2.throughput();
    println!("\nquery-evaluations/s: sim {qps:.0} | sweep {evals:.0}   target ≥ 5M evals/s: {}",
        if evals >= 5.0e6 { "HIT ✓" } else { "MISS ✗ (see EXPERIMENTS.md §Perf)" });
}
