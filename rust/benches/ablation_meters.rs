//! Ablation bench: does the *measurement methodology* change the
//! headline? The paper mixes four meters (§4.2); if meter bias were
//! large, the M1↔A100 comparison (and hence T and the 7.5 %) could be a
//! measurement artifact. We recompute the Eq. 9 threshold curve with
//! each system's energy read through its *simulated meter* instead of
//! the exact model, and check the optimum threshold is stable.

use hetsched::experiments::sweeps::{input_thresholds, threshold_sweep};
use hetsched::hw::catalog::{system_catalog, SystemId};
use hetsched::measure::meters::{Meter, NvmlMeter, PowermetricsMeter};
use hetsched::measure::trace::GroundTruthTrace;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::util::benchkit::bench_header;
use hetsched::util::rng::Xoshiro256;
use hetsched::util::tablefmt::{fmt_joules, Table};
use hetsched::workload::alpaca::AlpacaModel;
use hetsched::workload::Query;

fn main() {
    bench_header("Ablation — is the threshold robust to meter error?");
    let systems = system_catalog();
    let m1 = &systems[SystemId::M1_PRO.0];
    let a100 = &systems[SystemId::SWING_A100.0];
    let perf = PerfModel::new(find_llm("Llama-2-7B").unwrap());
    let energy = EnergyModel::new(perf.clone());
    let queries: Vec<Query> = AlpacaModel::default()
        .trace(2024, 10_000)
        .iter()
        .map(|q| Query::new(q.id, q.input_tokens, 32))
        .collect();

    // exact-model curve
    let grid = input_thresholds();
    let exact = threshold_sweep(&queries, &energy, m1, a100, &grid, true);

    // measured curve: per-(m) mean energies read through each system's
    // §4.2 meter (powermetrics for the M1, NVML for the A100), 3 trials
    let mut rng = Xoshiro256::seed_from(17);
    let pm_meter = PowermetricsMeter::default();
    let nv_meter = NvmlMeter::default();
    let mut measured_energy = |spec: &hetsched::hw::spec::SystemSpec, m: u32, n: u32| -> f64 {
        let gt = GroundTruthTrace::new(perf.power_model(spec, m, n), spec, 20.0);
        let meter: &dyn Meter = if spec.name == "M1-Pro" { &pm_meter } else { &nv_meter };
        let trials = 3;
        (0..trials).map(|_| meter.measure(&gt, &mut rng).energy_j).sum::<f64>() / trials as f64
    };

    // memoized per distinct m (the sweep holds n = 32)
    let mut distinct: Vec<u32> = queries.iter().map(|q| q.input_tokens).collect();
    distinct.sort_unstable();
    distinct.dedup();
    let mut m1_e = std::collections::BTreeMap::new();
    let mut a100_e = std::collections::BTreeMap::new();
    for &m in &distinct {
        m1_e.insert(m, measured_energy(m1, m, 32));
        a100_e.insert(m, measured_energy(a100, m, 32));
    }

    let mut best_t = 0u32;
    let mut best_e = f64::INFINITY;
    let mut rows = Vec::new();
    for &t in &grid {
        let e: f64 = queries
            .iter()
            .map(|q| {
                let m = q.input_tokens;
                if m <= t { m1_e[&m] } else { a100_e[&m] }
            })
            .sum();
        rows.push((t, e));
        if e < best_e {
            best_e = e;
            best_t = t;
        }
    }

    let mut table = Table::new(&["T_in", "exact-model energy", "meter-measured energy"]);
    for (i, &t) in grid.iter().enumerate() {
        table.row(&[
            t.to_string(),
            fmt_joules(exact.hybrid_energy_j[i]),
            fmt_joules(rows[i].1),
        ]);
    }
    print!("{}", table.ascii());
    println!(
        "optimum: exact model T={}   meter-measured T={}",
        exact.best_threshold, best_t
    );

    // robustness: the measured optimum must land within one grid step
    let exact_idx = grid.iter().position(|&t| t == exact.best_threshold).unwrap();
    let measured_idx = grid.iter().position(|&t| t == best_t).unwrap();
    assert!(
        (exact_idx as i64 - measured_idx as i64).abs() <= 1,
        "meter error moved the optimum from {} to {best_t}",
        exact.best_threshold
    );
    println!("robustness ✓ — §4.2 meter error does not move the threshold optimum");
}
