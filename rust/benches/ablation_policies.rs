//! Ablation bench (DESIGN.md §8): how much of the headline saving does
//! each design ingredient contribute? Compares, on the same Eq. 9
//! workload:
//!   - fixed threshold at the paper's T = 32
//!   - fixed threshold at the *offline-optimal* T
//!   - online adaptive threshold (no offline analysis needed)
//!   - per-query cost argmin (λ = 1) — the full Eq. 1 machinery
//!   - the oracle (identical to cost for batch; sanity rail)

use hetsched::hw::catalog::{system_catalog, SystemId};
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::adaptive::AdaptiveThresholdPolicy;
use hetsched::sched::cost::CostPolicy;
use hetsched::sched::oracle::oracle_assign;
use hetsched::sched::policy::{ClusterView, Policy};
use hetsched::sched::threshold::ThresholdPolicy;
use hetsched::util::benchkit::bench_header;
use hetsched::util::tablefmt::{fmt_joules, Align, Table};
use hetsched::workload::alpaca::{AlpacaModel, ALPACA_SIZE};
use hetsched::workload::Query;

fn total_energy(policy: &mut dyn Policy, queries: &[Query], energy: &EnergyModel) -> f64 {
    let systems = system_catalog();
    let depths = vec![0.0; systems.len()];
    let lens = vec![0usize; systems.len()];
    queries
        .iter()
        .map(|q| {
            let view = ClusterView { systems: &systems, queue_depth_s: &depths, queue_len: &lens };
            let sid = policy.assign(q, &view);
            energy.energy(&systems[sid.0], q.input_tokens, q.output_tokens)
        })
        .sum()
}

fn main() {
    bench_header("Ablation — which ingredient buys the saving?");
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries: Vec<Query> = AlpacaModel::default()
        .trace(2024, ALPACA_SIZE)
        .iter()
        .map(|q| Query::new(q.id, q.input_tokens, 32))
        .collect();

    let baseline: f64 = queries
        .iter()
        .map(|q| energy.energy(&systems[1], q.input_tokens, q.output_tokens))
        .sum();

    // offline-optimal fixed threshold
    let grid = hetsched::experiments::sweeps::input_thresholds();
    let curve = hetsched::experiments::sweeps::threshold_sweep(
        &queries, &energy, &systems[0], &systems[1], &grid, true,
    );

    let mut rows: Vec<(String, f64)> = Vec::new();
    let mut t32 = ThresholdPolicy::new(32, 32, SystemId::M1_PRO, SystemId::SWING_A100, energy.clone());
    rows.push(("fixed threshold T=32 (paper)".into(), total_energy(&mut t32, &queries, &energy)));
    let mut topt = ThresholdPolicy::new(
        curve.best_threshold, u32::MAX, SystemId::M1_PRO, SystemId::SWING_A100, energy.clone(),
    );
    rows.push((format!("fixed threshold T={} (offline opt)", curve.best_threshold),
               total_energy(&mut topt, &queries, &energy)));
    let mut adaptive = AdaptiveThresholdPolicy::new(8, SystemId::M1_PRO, SystemId::SWING_A100, energy.clone());
    rows.push(("adaptive threshold (online, from T=8)".into(), total_energy(&mut adaptive, &queries, &energy)));
    let mut cost = CostPolicy::new(1.0, energy.clone());
    rows.push(("cost argmin λ=1 (Eq. 1)".into(), total_energy(&mut cost, &queries, &energy)));
    let (oracle_assignments, _) = oracle_assign(&queries, &systems, &energy, 1.0);
    let oracle_e: f64 = queries
        .iter()
        .zip(&oracle_assignments)
        .map(|(q, s)| energy.energy(&systems[s.0], q.input_tokens, q.output_tokens))
        .sum();
    rows.push(("oracle (per-query optimum)".into(), oracle_e));

    let mut t = Table::new(&["policy", "energy", "saving vs all-A100"]).align(0, Align::Left);
    t.row(&["all-A100 baseline".into(), fmt_joules(baseline), "—".into()]);
    for (name, e) in &rows {
        t.row(&[name.clone(), fmt_joules(*e), format!("{:+.2}%", (1.0 - e / baseline) * 100.0)]);
    }
    print!("{}", t.ascii());

    // sanity rails
    let t32_e = rows[0].1;
    let topt_e = rows[1].1;
    let cost_e = rows[3].1;
    assert!(topt_e <= t32_e, "offline-optimal T must beat T=32");
    assert!(cost_e <= topt_e * 1.0001, "cost argmin must match/beat any fixed threshold");
    assert!((oracle_e - cost_e).abs() / oracle_e < 1e-9, "oracle == cost(λ=1) in batch");
    let adaptive_e = rows[2].1;
    assert!(adaptive_e <= baseline, "adaptive must at least not lose vs baseline");
    println!("\nordering checks ✓ (oracle == cost ≤ fixed-opt ≤ fixed-32; adaptive converges between)");
}
