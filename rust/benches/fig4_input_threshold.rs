//! Bench: regenerate **Figure 4** — hybrid datacenter energy (4a) and
//! runtime (4b) vs. input-token threshold T_in on Alpaca (Eq. 9), with
//! the single-hardware dashed lines.

use hetsched::experiments::sweeps::{input_thresholds, threshold_sweep};
use hetsched::hw::catalog::{system_catalog, SystemId};
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Table};
use hetsched::workload::alpaca::{AlpacaModel, ALPACA_SIZE};
use hetsched::workload::Query;

fn main() {
    bench_header("Figure 4 — input-threshold sweep (Eq. 9, Alpaca, n = 32)");
    let systems = system_catalog();
    let m1 = &systems[SystemId::M1_PRO.0];
    let a100 = &systems[SystemId::SWING_A100.0];
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries: Vec<Query> = AlpacaModel::default()
        .trace(2024, ALPACA_SIZE)
        .iter()
        .map(|q| Query::new(q.id, q.input_tokens, 32))
        .collect();

    let grid = input_thresholds();
    let c = threshold_sweep(&queries, &energy, m1, a100, &grid, true);

    let mut t = Table::new(&["T_in", "energy (4a)", "runtime (4b)", "vs all-A100"]);
    for ((&th, &e), &r) in c.thresholds.iter().zip(&c.hybrid_energy_j).zip(&c.hybrid_runtime_s) {
        t.row(&[
            th.to_string(),
            fmt_joules(e),
            fmt_secs(r),
            format!("{:+.2}%", (1.0 - e / c.all_big_energy_j) * 100.0),
        ]);
    }
    print!("{}", t.ascii());
    println!(
        "dashed: all-M1 {} / {}    all-A100 {} / {}",
        fmt_joules(c.all_small_energy_j), fmt_secs(c.all_small_runtime_s),
        fmt_joules(c.all_big_energy_j), fmt_secs(c.all_big_runtime_s)
    );
    println!(
        "optimum T_in = {} → {} ({:+.2}% vs all-A100)   [paper: T_in = 32]",
        c.best_threshold, fmt_joules(c.best_energy_j),
        (1.0 - c.best_energy_j / c.all_big_energy_j) * 100.0
    );

    // shape checks: U-curve dipping below both dashed lines, optimum in
    // the tens of tokens, runtime monotone cost (4b trade-off)
    assert!(c.best_energy_j < c.all_big_energy_j && c.best_energy_j < c.all_small_energy_j);
    assert!((16..=64).contains(&c.best_threshold), "optimum {}", c.best_threshold);
    let i32_idx = grid.iter().position(|&t| t == 32).unwrap();
    assert!(c.hybrid_runtime_s[i32_idx] > c.all_big_runtime_s, "energy saving must cost runtime");
    println!("shape checks vs paper Fig 4 ✓");

    let r = Bench::quick().run("52K-query × 16-threshold sweep", (queries.len() * grid.len()) as u64, || {
        black_box(threshold_sweep(&queries, &energy, m1, a100, &grid, true));
    });
    println!("{}", r.line());
}
