//! Bench: regenerate **Figure 3** — the Alpaca input/output token-count
//! distributions that drive Eq. 9/10 (52K queries).

use hetsched::experiments::fig3_alpaca;
use hetsched::experiments::figures::render_histogram;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::workload::alpaca::{AlpacaModel, ALPACA_SIZE};

fn main() {
    bench_header("Figure 3 — Alpaca token-count distributions");
    let trace = AlpacaModel::default().trace(2024, ALPACA_SIZE);
    let f = fig3_alpaca(&trace);

    println!("{}", render_histogram(&f.input_hist, "Fig 3(a): input tokens"));
    println!(
        "  median={:.0}  mean={:.1}  p90={:.0}  p99={:.0}  max={}\n",
        f.input_summary.median, f.input_summary.mean, f.input_summary.p90,
        f.input_summary.p99, f.input_summary.max
    );
    println!("{}", render_histogram(&f.output_hist, "Fig 3(b): output tokens"));
    println!(
        "  median={:.0}  mean={:.1}  p90={:.0}  p99={:.0}  max={}",
        f.output_summary.median, f.output_summary.mean, f.output_summary.p90,
        f.output_summary.p99, f.output_summary.max
    );

    // shape checks: right-skewed input dist centred in the tens of
    // tokens; broader output dist shifted right — the premise that makes
    // T = 32 interesting at all
    assert!(f.input_summary.median < f.output_summary.median);
    assert!(f.input_summary.mean > f.input_summary.median, "right skew");
    let below_t32 = trace.iter().filter(|q| q.input_tokens <= 32).count() as f64 / trace.len() as f64;
    println!("\nfraction of queries with m ≤ 32: {:.1}% (the mass the hybrid routes to the M1)", below_t32 * 100.0);
    assert!((0.4..0.9).contains(&below_t32));
    println!("shape checks vs paper Fig 3 ✓");

    let model = AlpacaModel::default();
    let r = Bench::quick().run("sample 52K-query trace", ALPACA_SIZE as u64, || {
        black_box(model.trace(1, ALPACA_SIZE));
    });
    println!("{}", r.line());
}
