//! Bench: the **headline result** — the paper's abstract claims the
//! hybrid strategy cuts CPU+GPU energy by **7.5 %** vs. a
//! workload-unaware baseline on Alpaca. Regenerates that comparison
//! (Eq. 9 framing) plus the extended policy table.

use hetsched::experiments::headline_savings;
use hetsched::hw::catalog::system_catalog;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Align, Table};
use hetsched::workload::alpaca::{AlpacaModel, ALPACA_SIZE};

fn main() {
    bench_header("Headline — hybrid vs workload-unaware baseline (paper: 7.5%)");
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries = AlpacaModel::default().trace(2024, ALPACA_SIZE);

    let r = headline_savings(&queries, &systems, &energy);
    println!(
        "Eq. 9  (input dist, n = 32):  {:+.2}% at T_in = 32   (optimum T = {})",
        r.eq9_saving_at_32 * 100.0, r.eq9_best_threshold
    );
    println!(
        "Eq. 10 (output dist, m = 32): {:+.2}% at T_out = 32  (optimum T = {})",
        r.eq10_saving_at_32 * 100.0, r.eq10_best_threshold
    );
    println!(
        "full-trace dual threshold:    {:+.2}% energy at {:+.1}% runtime\n",
        r.combined_saving * 100.0, r.runtime_increase_frac * 100.0
    );

    let mut t = Table::new(&["policy", "energy", "Σ service", "makespan", "→M1", "→A100", "→V100"])
        .align(0, Align::Left);
    for rep in &r.reports {
        let counts = rep.routing_counts();
        t.row(&[
            rep.policy.clone(),
            fmt_joules(rep.total_energy_j),
            fmt_secs(rep.total_service_s),
            fmt_secs(rep.makespan_s),
            counts.first().copied().unwrap_or(0).to_string(),
            counts.get(1).copied().unwrap_or(0).to_string(),
            counts.get(2).copied().unwrap_or(0).to_string(),
        ]);
    }
    print!("{}", t.ascii());

    // reproduction checks (paper: 7.5% at T = 32 on both axes)
    assert!((0.04..=0.15).contains(&r.eq9_saving_at_32), "Eq.9 saving off-band");
    assert!(r.eq10_saving_at_32 > 0.0 && r.combined_saving > 0.0);
    assert!(r.runtime_increase_frac > 0.0, "the §6.3 trade-off must appear");
    // workload-aware beats every workload-unaware policy on energy
    let hybrid_e = r.reports[1].total_energy_j;
    for rep in &r.reports[2..5] {
        assert!(hybrid_e < rep.total_energy_j, "{} beat the hybrid?!", rep.policy);
    }
    println!("\nreproduction checks ✓ (saving in band, trade-off present, hybrid beats unaware baselines)");

    let b = Bench::quick().run("full headline suite (6 policies × 52K)", queries.len() as u64 * 6, || {
        black_box(headline_savings(&queries, &systems, &energy));
    });
    println!("{}", b.line());
}
