//! Bench: regenerate **Figure 2** — runtime (a), throughput (b), and
//! energy-per-token (c) vs. *output* tokens (n ∈ 8..4096, m = 32), with
//! the paper's OOM/limit gaps (missing data points in Fig. 2).

use hetsched::experiments::output_sweep;
use hetsched::hw::catalog::system_catalog;
use hetsched::model::llm_catalog;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_secs, Align, Table};

fn main() {
    bench_header("Figure 2 — output-token sweep (m = 32)");
    let rows = output_sweep(&llm_catalog(), &system_catalog());

    for model in ["Falcon-7B", "Llama-2-7B", "Mistral-7B"] {
        println!("\n--- {model} ---");
        let mut t = Table::new(&["n", "R (2a)", "tok/s (2b)", "J/token (2c)", "system"])
            .align(4, Align::Left);
        for r in rows.iter().filter(|r| r.model == model) {
            if let Some(reason) = r.skipped {
                t.row(&[r.tokens.to_string(), reason.into(), "-".into(), "-".into(), r.system.clone()]);
            } else {
                t.row(&[
                    r.tokens.to_string(),
                    fmt_secs(r.runtime_s),
                    format!("{:.1}", r.throughput_tok_s),
                    format!("{:.2}", r.energy_per_token_j),
                    r.system.clone(),
                ]);
            }
        }
        print!("{}", t.ascii());
    }

    // ---- shape + gap assertions -----------------------------------------
    let get = |model: &str, sys: &str, n: u32| {
        rows.iter()
            .find(|r| r.model == model && r.system == sys && r.tokens == n)
            .unwrap()
    };
    // (2a/§5.5) output growth dominates input growth (vs fig1 at same token count)
    // (2b) throughput declines with n on every feasible system
    for sys in ["M1-Pro", "Swing-A100", "Palmetto-V100"] {
        let hi = get("Llama-2-7B", sys, 64).throughput_tok_s;
        let lo = get("Llama-2-7B", sys, 512).throughput_tok_s;
        assert!(lo < hi, "{sys}: throughput must decline");
    }
    // (2c) energy/token rises with n
    assert!(
        get("Llama-2-7B", "Swing-A100", 4096).energy_per_token_j
            > get("Llama-2-7B", "Swing-A100", 64).energy_per_token_j
    );
    // the paper's exact gaps: V100+Falcon OOM > 1024; V100 all > 2048;
    // M1 > 512; Falcon absent on M1 entirely
    assert_eq!(get("Falcon-7B", "Palmetto-V100", 2048).skipped, Some("OOM"));
    assert!(get("Falcon-7B", "Palmetto-V100", 1024).skipped.is_none());
    assert_eq!(get("Llama-2-7B", "Palmetto-V100", 4096).skipped, Some("OOM"));
    assert_eq!(get("Llama-2-7B", "M1-Pro", 1024).skipped, Some("ctx-limit"));
    assert!(rows
        .iter()
        .filter(|r| r.model == "Falcon-7B" && r.system == "M1-Pro")
        .all(|r| r.skipped.is_some()));
    println!("\nshape checks vs paper Fig 2 ✓ (decline, rise, OOM gaps match §5.4)");

    let models = llm_catalog();
    let systems = system_catalog();
    let r = Bench::quick().run("full fig2 sweep", (3 * 3 * 10) as u64, || {
        black_box(output_sweep(&models, &systems));
    });
    println!("{}", r.line());
}
