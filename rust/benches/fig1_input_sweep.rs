//! Bench: regenerate **Figure 1** — runtime (a), throughput (b), and
//! energy-per-token (c) vs. *input* tokens (m ∈ 8..2048, n = 32) for all
//! three models × three systems, plus shape checks against the paper.

use hetsched::experiments::input_sweep;
use hetsched::hw::catalog::system_catalog;
use hetsched::model::llm_catalog;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_secs, Align, Table};

fn main() {
    bench_header("Figure 1 — input-token sweep (n = 32)");
    let rows = input_sweep(&llm_catalog(), &system_catalog());

    for model in ["Falcon-7B", "Llama-2-7B", "Mistral-7B"] {
        println!("\n--- {model} ---");
        let mut t = Table::new(&["m", "R (1a)", "tok/s (1b)", "J/token (1c)", "system"])
            .align(4, Align::Left);
        for r in rows.iter().filter(|r| r.model == model) {
            if let Some(reason) = r.skipped {
                t.row(&[r.tokens.to_string(), reason.into(), "-".into(), "-".into(), r.system.clone()]);
            } else {
                t.row(&[
                    r.tokens.to_string(),
                    fmt_secs(r.runtime_s),
                    format!("{:.1}", r.throughput_tok_s),
                    format!("{:.2}", r.energy_per_token_j),
                    r.system.clone(),
                ]);
            }
        }
        print!("{}", t.ascii());
    }

    // ---- shape assertions (what "reproduced" means per DESIGN.md §4) ----
    let llama = |sys: &str, m: u32| {
        rows.iter()
            .find(|r| r.model == "Llama-2-7B" && r.system == sys && r.tokens == m)
            .unwrap()
    };
    // (1a) runtime rises with m on every system; M1 steepest overall
    assert!(llama("M1-Pro", 2048).runtime_s > 4.0 * llama("Swing-A100", 2048).runtime_s);
    // (1b) throughput rooflines: steep rise then flattening on the A100
    let g1 = llama("Swing-A100", 512).throughput_tok_s / llama("Swing-A100", 8).throughput_tok_s;
    let g2 = llama("Swing-A100", 2048).throughput_tok_s / llama("Swing-A100", 512).throughput_tok_s;
    assert!(g1 > 2.0 && g2 < g1 / 2.0, "roofline shape: {g1:.2} then {g2:.2}");
    // (1c) M1↔A100 energy crossover: M1 cheaper at 8, dearer at 2048
    assert!(llama("M1-Pro", 8).energy_per_token_j < llama("Swing-A100", 8).energy_per_token_j);
    assert!(llama("M1-Pro", 2048).energy_per_token_j > llama("Swing-A100", 2048).energy_per_token_j);
    println!("\nshape checks vs paper Fig 1 ✓ (rise, roofline, M1↔A100 crossover)");

    let models = llm_catalog();
    let systems = system_catalog();
    let r = Bench::quick().run("full fig1 sweep", (3 * 3 * 9) as u64, || {
        black_box(input_sweep(&models, &systems));
    });
    println!("{}", r.line());
}
