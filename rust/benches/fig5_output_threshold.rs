//! Bench: regenerate **Figure 5** — hybrid datacenter energy (5a) and
//! runtime (5b) vs. output-token threshold T_out on Alpaca (Eq. 10).
//! The sweep stops at 512, the M1's generation ceiling (§6.2).

use hetsched::experiments::sweeps::{output_thresholds, threshold_sweep};
use hetsched::hw::catalog::{system_catalog, SystemId};
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Table};
use hetsched::workload::alpaca::{AlpacaModel, ALPACA_SIZE};
use hetsched::workload::Query;

fn main() {
    bench_header("Figure 5 — output-threshold sweep (Eq. 10, Alpaca, m = 32)");
    let systems = system_catalog();
    let m1 = &systems[SystemId::M1_PRO.0];
    let a100 = &systems[SystemId::SWING_A100.0];
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries: Vec<Query> = AlpacaModel::default()
        .trace(2024, ALPACA_SIZE)
        .iter()
        .map(|q| Query::new(q.id, 32, q.output_tokens))
        .collect();

    let grid = output_thresholds();
    assert_eq!(*grid.last().unwrap(), 512, "paper sweeps T_out only to the M1's 512 cap");
    let c = threshold_sweep(&queries, &energy, m1, a100, &grid, false);

    let mut t = Table::new(&["T_out", "energy (5a)", "runtime (5b)", "vs all-A100"]);
    for ((&th, &e), &r) in c.thresholds.iter().zip(&c.hybrid_energy_j).zip(&c.hybrid_runtime_s) {
        t.row(&[
            th.to_string(),
            fmt_joules(e),
            fmt_secs(r),
            format!("{:+.2}%", (1.0 - e / c.all_big_energy_j) * 100.0),
        ]);
    }
    print!("{}", t.ascii());
    println!(
        "dashed: all-A100 {} / {}",
        fmt_joules(c.all_big_energy_j), fmt_secs(c.all_big_runtime_s)
    );
    println!(
        "optimum T_out = {} → {} ({:+.2}% vs all-A100)   [paper: T_out = 32]",
        c.best_threshold, fmt_joules(c.best_energy_j),
        (1.0 - c.best_energy_j / c.all_big_energy_j) * 100.0
    );

    // shape checks: minimum exists at a small threshold; pushing the
    // threshold to the M1's ceiling *loses* energy (the 5a upturn)
    assert!(c.best_energy_j < c.all_big_energy_j);
    assert!((16..=96).contains(&c.best_threshold), "optimum {}", c.best_threshold);
    let last = *c.hybrid_energy_j.last().unwrap();
    assert!(last > c.best_energy_j * 1.05, "curve must turn up toward T=512");
    println!("shape checks vs paper Fig 5 ✓");

    let r = Bench::quick().run("52K-query × 14-threshold sweep", (queries.len() * grid.len()) as u64, || {
        black_box(threshold_sweep(&queries, &energy, m1, a100, &grid, false));
    });
    println!("{}", r.line());
}
