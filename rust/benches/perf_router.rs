//! §Perf bench: the L3 routing hot path. Target (DESIGN.md §7): ≥1 M
//! policy decisions/s single-thread — the coordinator must never be the
//! bottleneck against ms-scale inference service times.

use hetsched::config::schema::PolicyConfig;
use hetsched::hw::catalog::system_catalog;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::policy::{build_policy, ClusterView};
use hetsched::util::benchkit::{bench_header, black_box, Bench};
use hetsched::workload::alpaca::AlpacaModel;

fn main() {
    bench_header("§Perf — router hot path (policy decisions/s)");
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries = AlpacaModel::default().trace(7, 100_000);
    let depths = vec![0.0f64; systems.len()];
    let lens = vec![0usize; systems.len()];

    let configs = [
        PolicyConfig::Threshold { t_in: 32, t_out: 32, small: "M1-Pro".into(), big: "Swing-A100".into() },
        PolicyConfig::Cost { lambda: 1.0 },
        PolicyConfig::RoundRobin,
        PolicyConfig::JoinShortestQueue,
    ];

    let bench = Bench::default();
    let mut reports = Vec::new();
    for cfg in &configs {
        let mut policy = build_policy(cfg, energy.clone(), &systems);
        let r = bench.run(&format!("assign × 100K [{}]", policy.name()), queries.len() as u64, || {
            let view = ClusterView { systems: &systems, queue_depth_s: &depths, queue_len: &lens };
            for q in &queries {
                black_box(policy.assign(q, &view));
            }
        });
        println!("{}", r.line());
        reports.push((policy.name(), r));
    }

    println!();
    let mut all_ok = true;
    for (name, r) in &reports {
        let dps = r.throughput();
        let ok = dps >= 1.0e6;
        all_ok &= ok;
        println!(
            "{name:<40} {dps:>12.0} decisions/s   target ≥ 1M: {}",
            if ok { "HIT ✓" } else { "MISS ✗" }
        );
    }
    assert!(all_ok, "router hot-path target missed — see EXPERIMENTS.md §Perf");
}
