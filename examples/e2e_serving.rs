//! **End-to-end serving driver** (the repo's full-stack proof): serve a
//! batched, mixed-size request stream through the paper's threshold
//! router, reporting latency, throughput, routing, and virtual-energy
//! attribution.
//!
//! With `--features pjrt` and `make artifacts`, workers execute the
//! AOT-compiled byte-level transformer through PJRT (L1 Pallas kernels →
//! L2 JAX prefill/decode HLO → L3 rust router/batcher/workers). Without
//! them, workers run the deterministic model-driven sim backend, so the
//! full topology still exercises end to end:
//!
//! ```bash
//! cargo run --release --example e2e_serving
//! ```
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use hetsched::config::schema::{ExperimentConfig, PolicyConfig};
use hetsched::coordinator::server::Server;
use hetsched::runtime::tokenizer::ByteTokenizer;
use hetsched::util::error::Result;
use hetsched::util::rng::Xoshiro256;
use hetsched::util::stats::percentile;
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Align, Table};
use hetsched::workload::alpaca::AlpacaModel;
use std::time::Instant;

const N_REQUESTS: usize = 48;
const GEN_TOKENS: u32 = 24;

fn main() -> Result<()> {
    let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut cfg = ExperimentConfig::default();
    cfg.policy = PolicyConfig::Threshold {
        t_in: 32,
        t_out: 32,
        small: "M1-Pro".into(),
        big: "Swing-A100".into(),
    };
    cfg.serve.gen_tokens = GEN_TOKENS;
    cfg.serve.max_batch = 8;
    cfg.serve.max_wait_s = 0.01;

    cfg.serve.artifacts_dir = artifacts.to_string_lossy().into_owned();
    let pjrt_active = Server::default_backend_is_pjrt(&cfg);
    if !pjrt_active {
        eprintln!("serving through the model-driven sim backend");
        if artifacts.join("manifest.json").exists() {
            eprintln!("(artifacts found, but this build lacks --features pjrt)");
        } else {
            eprintln!("(build with --features pjrt and run `make artifacts` for real PJRT)");
        }
    }
    println!("starting server: {} policy over {:?}", cfg.policy.name(),
        cfg.cluster.systems.iter().map(|s| s.name).collect::<Vec<_>>());
    let t_boot = Instant::now();
    let server = Server::start(&cfg, Server::default_factory(&cfg)?)?;
    let handle = server.handle();
    println!("server up ({} workers compiling engines lazily)", cfg.cluster.systems.len());

    // ---- drive a mixed-size request stream ------------------------------
    let tok = ByteTokenizer;
    let model = AlpacaModel::default();
    let mut rng = Xoshiro256::seed_from(2024);
    let corpus = "the quick brown fox jumps over the lazy dog while the data \
                  center hums with the sound of a thousand fans and the \
                  scheduler weighs joules against seconds ";
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut prompt_sizes = Vec::new();
    for _ in 0..N_REQUESTS {
        // prompt lengths follow the Alpaca input distribution (capped to
        // the largest AOT bucket)
        let m = (model.sample_input(&mut rng) as usize).clamp(2, 200);
        let text: String = corpus.chars().cycle().take(m).collect();
        prompt_sizes.push(m + 1);
        rxs.push(handle.submit(tok.encode(&text), Some(GEN_TOKENS)).expect("admitted"));
    }
    println!("submitted {N_REQUESTS} requests (prompt sizes {}–{} tokens)",
        prompt_sizes.iter().min().unwrap(), prompt_sizes.iter().max().unwrap());

    // ---- collect --------------------------------------------------------
    let mut responses = Vec::new();
    for rx in rxs {
        responses.push(rx.recv()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let boot = t_boot.elapsed().as_secs_f64() - wall;

    // ---- report ----------------------------------------------------------
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let lats: Vec<f64> = responses.iter().map(|r| r.latency_s).collect();
    let mut by_system: std::collections::BTreeMap<String, (usize, f64, f64, f64)> = Default::default();
    for r in &responses {
        let e = by_system.entry(r.system_name.clone()).or_default();
        e.0 += 1;
        e.1 += r.latency_s;
        e.2 += r.energy_j;
        e.3 += r.decode_s;
    }

    println!("\n=== end-to-end serving report ===");
    println!(
        "backend: {}",
        if pjrt_active { "PJRT (real artifacts)" } else { "sim (perf-model timings)" }
    );
    if pjrt_active {
        println!("engine boot (compile HLO once per worker): {}", fmt_secs(boot.max(0.0)));
    } else {
        println!("engine boot: {}", fmt_secs(boot.max(0.0)));
        println!(
            "NOTE: sim generation returns instantly, so the wall-clock latency and\n\
             throughput below measure dispatch overhead only; energy and phase times\n\
             are model-derived — do not record these as PJRT numbers"
        );
    }
    println!("wall time for {N_REQUESTS} requests: {}", fmt_secs(wall));
    println!("generated {total_tokens} tokens → cluster throughput {:.1} tok/s, {:.2} req/s",
        total_tokens as f64 / wall, N_REQUESTS as f64 / wall);
    println!("latency: p50 {}  p90 {}  p99 {}",
        fmt_secs(percentile(&lats, 50.0)),
        fmt_secs(percentile(&lats, 90.0)),
        fmt_secs(percentile(&lats, 99.0)));

    let mut t = Table::new(&["system", "served", "mean latency", "decode tok/s", "virtual energy"])
        .align(0, Align::Left);
    for (name, (count, lat, e, dec)) in &by_system {
        let toks = *count as f64 * GEN_TOKENS as f64;
        t.row(&[
            name.clone(),
            count.to_string(),
            fmt_secs(lat / *count as f64),
            format!("{:.1}", toks / dec.max(1e-9)),
            fmt_joules(*e),
        ]);
    }
    print!("{}", t.ascii());

    // sample output, proving real tokens flow end to end
    let sample = &responses[0];
    println!("\nsample continuation (system {}):", sample.system_name);
    println!("  {:?}", tok.decode(&sample.tokens));
    println!("\nmetrics: {}", handle.metrics_json());

    server.shutdown();
    println!("server drained and shut down cleanly");
    Ok(())
}
