//! Carbon-aware scheduling study (extension): the same hybrid cluster,
//! but the objective is grams of CO₂ rather than joules. When the GPU
//! datacenter sits on a dirty grid and the M1 fleet on a clean one (or
//! mid-day solar shifts intensity), the optimal routing changes — energy
//! and carbon optima are *not* the same schedule.
//!
//! ```bash
//! cargo run --release --example carbon_aware
//! ```

use hetsched::hw::catalog::system_catalog;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::carbon::{total_grams, CarbonPolicy, CarbonProfile, J_PER_KWH};
use hetsched::sched::policy::{ClusterView, Policy};
use hetsched::util::tablefmt::{Align, Table};
use hetsched::workload::alpaca::AlpacaModel;

fn main() {
    let systems = system_catalog();
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let queries = AlpacaModel::default().trace(2024, 20_000);
    let depths = vec![0.0; systems.len()];
    let lens = vec![0usize; systems.len()];

    // scenario: M1 fleet behind a hydro-heavy grid; GPUs on a mixed grid
    // with a solar dip
    let scenarios: Vec<(&str, Vec<CarbonProfile>)> = vec![
        (
            "uniform grid (300 g/kWh everywhere)",
            vec![CarbonProfile::flat(300.0); 3],
        ),
        (
            "clean edge (40 g) vs coal DC (800 g)",
            vec![CarbonProfile::flat(40.0), CarbonProfile::flat(800.0), CarbonProfile::flat(800.0)],
        ),
        (
            "solar DC grid (dips mid-day)",
            vec![CarbonProfile::flat(300.0), CarbonProfile::solar_grid(600.0), CarbonProfile::solar_grid(600.0)],
        ),
    ];

    let mut table = Table::new(&["scenario", "policy", "kg CO₂", "→M1", "→A100"]).align(0, Align::Left).align(1, Align::Left);
    for (name, profiles) in &scenarios {
        for (pname, lambda_carbon) in [("energy-optimal", false), ("carbon-optimal", true)] {
            let mut assignment = Vec::with_capacity(queries.len());
            if lambda_carbon {
                let mut p = CarbonPolicy::new(1.0, energy.clone(), profiles.clone());
                for q in &queries {
                    let view = ClusterView { systems: &systems, queue_depth_s: &depths, queue_len: &lens };
                    assignment.push(p.assign(q, &view));
                }
            } else {
                let mut p = hetsched::sched::cost::CostPolicy::new(1.0, energy.clone());
                for q in &queries {
                    let view = ClusterView { systems: &systems, queue_depth_s: &depths, queue_len: &lens };
                    assignment.push(p.assign(q, &view));
                }
            }
            let grams = total_grams(&queries, &assignment, &systems, &energy, profiles, 0.0);
            let m1 = assignment.iter().filter(|s| s.0 == 0).count();
            let a100 = assignment.iter().filter(|s| s.0 == 1).count();
            table.row(&[
                if lambda_carbon { String::new() } else { name.to_string() },
                pname.into(),
                format!("{:.2}", grams / 1000.0),
                m1.to_string(),
                a100.to_string(),
            ]);
        }
    }
    println!("carbon vs energy objectives on 20K Alpaca queries");
    print!("{}", table.ascii());

    // context: what one query costs
    let e = energy.energy(&systems[1], 32, 64);
    println!("\n(scale: one median query on the A100 ≈ {:.0} J ≈ {:.2} g CO₂ at 300 g/kWh)",
        e, e / J_PER_KWH * 300.0);
    println!("takeaway: with asymmetric grids the carbon-optimal router shifts");
    println!("substantially more traffic to the clean fleet than the energy-optimal one.");
}
