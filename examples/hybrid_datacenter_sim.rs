//! Hybrid-datacenter study (§6 of the paper) at full scale: the 52K
//! Alpaca trace, both threshold sweeps (Eq. 9/10), the λ trade-off of
//! Eq. 1, and a fleet-sizing extension (k × M1 per A100).
//!
//! ```bash
//! cargo run --release --example hybrid_datacenter_sim
//! ```

use hetsched::experiments::sweeps::{input_thresholds, output_thresholds, threshold_sweep};
use hetsched::hw::catalog::{system_catalog, SystemId};
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::oracle::oracle_assign;
use hetsched::util::tablefmt::{fmt_joules, fmt_secs, Table};
use hetsched::workload::alpaca::{AlpacaModel, ALPACA_SIZE};
use hetsched::workload::Query;

fn main() {
    let systems = system_catalog();
    let m1 = &systems[SystemId::M1_PRO.0];
    let a100 = &systems[SystemId::SWING_A100.0];
    let energy = EnergyModel::new(PerfModel::new(find_llm("Llama-2-7B").unwrap()));
    let trace = AlpacaModel::default().trace(2024, ALPACA_SIZE);
    println!("Alpaca trace: {} queries\n", trace.len());

    // ---- Fig 4 (Eq. 9): input-token threshold -------------------------
    let q_in: Vec<Query> = trace.iter().map(|q| Query::new(q.id, q.input_tokens, 32)).collect();
    let c_in = threshold_sweep(&q_in, &energy, m1, a100, &input_thresholds(), true);
    println!(
        "Fig 4 — input threshold: optimum T_in={} → {} ({:.2}% below all-A100)",
        c_in.best_threshold,
        fmt_joules(c_in.best_energy_j),
        (1.0 - c_in.best_energy_j / c_in.all_big_energy_j) * 100.0
    );

    // ---- Fig 5 (Eq. 10): output-token threshold ------------------------
    let q_out: Vec<Query> = trace.iter().map(|q| Query::new(q.id, 32, q.output_tokens)).collect();
    let c_out = threshold_sweep(&q_out, &energy, m1, a100, &output_thresholds(), false);
    println!(
        "Fig 5 — output threshold: optimum T_out={} → {} ({:.2}% below all-A100)",
        c_out.best_threshold,
        fmt_joules(c_out.best_energy_j),
        (1.0 - c_out.best_energy_j / c_out.all_big_energy_j) * 100.0
    );

    // ---- λ trade-off (Eq. 1, the knob the paper defines but fixes) ----
    println!("\nλ trade-off (oracle per-query argmin of U = λE + (1−λ)R):");
    let mut t = Table::new(&["λ", "energy", "Σ runtime", "→M1", "→A100", "→V100"]);
    for lambda in [0.0, 0.25, 0.5, 0.75, 0.9, 1.0] {
        let (assign, _) = oracle_assign(&trace, &systems, &energy, lambda);
        let mut e_total = 0.0;
        let mut r_total = 0.0;
        let mut counts = [0u64; 3];
        for (q, sid) in trace.iter().zip(&assign) {
            e_total += energy.energy(&systems[sid.0], q.input_tokens, q.output_tokens);
            r_total += energy.runtime(&systems[sid.0], q.input_tokens, q.output_tokens);
            counts[sid.0] += 1;
        }
        t.row(&[
            format!("{lambda:.2}"),
            fmt_joules(e_total),
            fmt_secs(r_total),
            counts[0].to_string(),
            counts[1].to_string(),
            counts[2].to_string(),
        ]);
    }
    print!("{}", t.ascii());
    println!("(λ=0 minimizes runtime, λ=1 minimizes energy — the Pareto knob of Eq. 1)");

    // ---- extension: fleet sizing (k × M1 per A100) ----------------------
    // Energy totals don't depend on node counts, but makespan does: how
    // many M1s must back one A100 before the hybrid stops being slower?
    println!("\nFleet sizing (makespan of the T=32 input-split, Eq. 9 workload):");
    let mut t = Table::new(&["M1 nodes", "M1 makespan", "A100 makespan", "cluster makespan"]);
    let small_work: f64 = q_in
        .iter()
        .filter(|q| q.input_tokens <= 32)
        .map(|q| energy.runtime(m1, q.input_tokens, q.output_tokens))
        .sum();
    let big_work: f64 = q_in
        .iter()
        .filter(|q| q.input_tokens > 32)
        .map(|q| energy.runtime(a100, q.input_tokens, q.output_tokens))
        .sum();
    for k in [1usize, 2, 4, 8, 16] {
        let m1_span = small_work / k as f64;
        let span = m1_span.max(big_work);
        t.row(&[
            k.to_string(),
            fmt_secs(m1_span),
            fmt_secs(big_work),
            fmt_secs(span),
        ]);
    }
    print!("{}", t.ascii());
    println!("(the paper's single M1 is the throughput bottleneck; ~the fleet ratio");
    println!(" where M1 makespan dips below the A100's is the balanced design point)");
}
