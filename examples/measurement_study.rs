//! Measurement-methodology study: how accurate are the paper's four
//! energy meters (§4.2)? The paper reports numbers from NVML, RAPL,
//! powermetrics, and AMD µProf without quantifying their attribution
//! error — here we run each simulated meter against ground truth and
//! report bias/spread, plus the sampling-interval sensitivity.
//!
//! ```bash
//! cargo run --release --example measurement_study
//! ```

use hetsched::hw::catalog::system_catalog;
use hetsched::measure::meters::{AmdUprofMeter, Meter, NvmlMeter, PowermetricsMeter, RaplMeter};
use hetsched::measure::trace::GroundTruthTrace;
use hetsched::model::find_llm;
use hetsched::perf::model::PerfModel;
use hetsched::util::rng::Xoshiro256;
use hetsched::util::stats::{mean, percentile};
use hetsched::util::tablefmt::{Align, Table};

fn error_stats(meter: &dyn Meter, trace: &GroundTruthTrace, trials: usize, seed: u64) -> (f64, f64) {
    let mut rng = Xoshiro256::seed_from(seed);
    let errs: Vec<f64> = (0..trials).map(|_| meter.measure(trace, &mut rng).rel_error * 100.0).collect();
    let abs: Vec<f64> = errs.iter().map(|e| e.abs()).collect();
    (mean(&errs), percentile(&abs, 95.0))
}

fn main() {
    let systems = system_catalog();
    let perf = PerfModel::new(find_llm("Llama-2-7B").unwrap());

    // a mid-size query on the A100 node, with 30 W of unrelated
    // background load the meters must not misattribute
    let spec = &systems[1];
    let gt = GroundTruthTrace::new(perf.power_model(spec, 256, 128), spec, 30.0);
    println!(
        "workload: Llama-2-7B (m=256, n=128) on {} — true task energy {:.1} J over {:.1} s\n",
        spec.name,
        gt.true_task_energy(),
        gt.duration()
    );

    println!("=== meter accuracy (200 trials each; error vs ground truth) ===");
    let mut t = Table::new(&["meter", "models (§4.2)", "mean bias %", "p95 |error| %"])
        .align(0, Align::Left)
        .align(1, Align::Left);
    let meters: Vec<(Box<dyn Meter>, &str)> = vec![
        (Box::new(NvmlMeter::default()), "PyJoules→NVML polling (Eq. 5)"),
        (Box::new(PowermetricsMeter::default()), "powermetrics + α factor (Eq. 6)"),
        (Box::new(RaplMeter::default()), "RAPL w/ idle subtraction (Eq. 7)"),
        (Box::new(AmdUprofMeter::default()), "µProf per-core + residency (Eq. 8)"),
    ];
    for (m, desc) in &meters {
        let (bias, p95) = error_stats(m.as_ref(), &gt, 200, 42);
        t.row(&[m.name().into(), desc.to_string(), format!("{bias:+.2}"), format!("{p95:.2}")]);
    }
    print!("{}", t.ascii());

    println!("\n=== sampling-interval sensitivity (NVML-style meter) ===");
    let mut t = Table::new(&["interval", "mean bias %", "p95 |error| %"]);
    for interval in [0.01, 0.05, 0.2, 0.5, 1.0, 2.0] {
        let m = NvmlMeter { interval_s: interval, sensor_noise: 0.02 };
        let (bias, p95) = error_stats(&m, &gt, 200, 7);
        t.row(&[format!("{:.0} ms", interval * 1e3), format!("{bias:+.2}"), format!("{p95:.2}")]);
    }
    print!("{}", t.ascii());
    println!("(the paper's 200 ms powermetrics / 100 ms µProf cadences sit in the");
    println!(" flat region for multi-second queries — but sub-second queries at the");
    println!(" paper's T=32 routing boundary are exactly where coarse meters blur)");

    println!("\n=== idle-baseline drift (RAPL's weak spot) ===");
    let mut t = Table::new(&["idle drift", "mean bias %"]);
    for drift in [-20.0, -10.0, 0.0, 10.0, 20.0] {
        let m = RaplMeter { idle_drift_w: drift, ..Default::default() };
        let (bias, _) = error_stats(&m, &gt, 100, 11);
        t.row(&[format!("{drift:+.0} W"), format!("{bias:+.2}")]);
    }
    print!("{}", t.ascii());
    println!("(Eq. 7's idle subtraction converts baseline drift directly into bias)");
}
