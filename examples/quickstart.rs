//! Quickstart: the library in ~60 lines.
//!
//! 1. Build the paper's cluster (Table 1) and energy model.
//! 2. Ask the cost function (Eq. 1) where a query should run.
//! 3. Run the threshold scheduler over a small Alpaca trace and compare
//!    against the all-A100 baseline.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hetsched::config::schema::PolicyConfig;
use hetsched::hw::catalog::system_catalog;
use hetsched::model::find_llm;
use hetsched::perf::energy::EnergyModel;
use hetsched::perf::model::PerfModel;
use hetsched::sched::policy::build_policy;
use hetsched::sim::engine::{simulate, SimOptions};
use hetsched::util::tablefmt::fmt_joules;
use hetsched::workload::alpaca::AlpacaModel;

fn main() {
    // --- 1. cluster + energy model -------------------------------------
    let systems = system_catalog(); // M1-Pro, Swing-A100, Palmetto-V100
    let llama = find_llm("Llama-2-7B").unwrap();
    let energy = EnergyModel::new(PerfModel::new(llama));

    // --- 2. per-query costs (Eq. 1: U = λE + (1−λ)R) --------------------
    println!("Where should a query run? (E in J, R in s)\n");
    for (m, n) in [(8u32, 8u32), (32, 32), (512, 128)] {
        println!("query m={m:4} n={n:4}:");
        for spec in &systems {
            let e = energy.energy(spec, m, n);
            let r = energy.runtime(spec, m, n);
            println!("    {:<14} E={e:8.1} J   R={r:7.2} s", spec.name);
        }
    }

    // --- 3. threshold scheduling vs baseline on Alpaca ------------------
    let queries = AlpacaModel::default().trace(2024, 5_000);
    let run = |cfg: &PolicyConfig| {
        let mut p = build_policy(cfg, energy.clone(), &systems);
        simulate(&queries, &systems, p.as_mut(), &energy, &SimOptions::default())
    };
    let baseline = run(&PolicyConfig::AllOn("Swing-A100".into()));
    let hybrid = run(&PolicyConfig::Threshold {
        t_in: 32,
        t_out: 32,
        small: "M1-Pro".into(),
        big: "Swing-A100".into(),
    });

    println!("\n5,000 Alpaca queries:");
    println!("  all-A100 baseline : {}", fmt_joules(baseline.total_energy_j));
    println!(
        "  hybrid threshold  : {}  ({:.2}% energy saved)",
        fmt_joules(hybrid.total_energy_j),
        (1.0 - hybrid.total_energy_j / baseline.total_energy_j) * 100.0
    );
    println!(
        "  routed to M1-Pro  : {} of {} queries",
        hybrid.routing_counts()[0],
        queries.len()
    );
    println!("\nNext: `hetsched headline` for the paper's full result, or");
    println!("`cargo run --release --example e2e_serving` for live serving.");
}
