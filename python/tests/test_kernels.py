"""L1 correctness: every Pallas kernel vs. its pure-jnp oracle.

hypothesis sweeps shapes/dtypes/seeds; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, decode, ffn, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- attention

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_blocks=st.integers(1, 4),
    k_extra_blocks=st.integers(0, 3),
    d=st.sampled_from([8, 16, 32, 64]),
    block=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
)
def test_flash_attention_matches_ref(seed, s_blocks, k_extra_blocks, d, block, causal):
    s = s_blocks * block
    t = s + k_extra_blocks * block
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(keys[0], (s, d), jnp.float32)
    k = _rand(keys[1], (t, d), jnp.float32)
    v = _rand(keys[2], (t, d), jnp.float32)
    got = attention.flash_attention(q, k, v, causal=causal, block_q=block, block_k=block)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(1, 4),
    s=st.sampled_from([16, 32, 64]),
    d=st.sampled_from([16, 32]),
)
def test_mha_flash_matches_ref(seed, h, s, d):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(keys[0], (h, s, d), jnp.float32)
    k = _rand(keys[1], (h, s, d), jnp.float32)
    v = _rand(keys[2], (h, s, d), jnp.float32)
    got = attention.mha_flash(q, k, v)
    want = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_extreme_logits_no_nan():
    # online softmax must stay finite for large-magnitude inputs
    q = jnp.full((32, 16), 30.0)
    k = jnp.full((32, 16), 30.0)
    v = jnp.ones((32, 16))
    out = attention.flash_attention(q, k, v, causal=True)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_flash_attention_rejects_bad_blocks():
    q = jnp.zeros((10, 8))
    with pytest.raises(ValueError):
        attention.flash_attention(q, q, q, block_q=4, block_k=4)


def test_flash_attention_first_row_attends_self_only():
    # causal: row 0 (with S == T) sees only key 0 → output == v[0]
    key = jax.random.PRNGKey(1)
    q, k, v = (_rand(kk, (32, 16), jnp.float32) for kk in jax.random.split(key, 3))
    out = attention.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out[0], v[0], rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- decode

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(1, 4),
    c=st.sampled_from([64, 128, 256]),
    d=st.sampled_from([16, 32]),
    block_c=st.sampled_from([32, 64]),
    pos_frac=st.floats(0.01, 1.0),
)
def test_decode_attention_matches_ref(seed, h, c, d, block_c, pos_frac):
    pos = max(1, int(c * pos_frac))
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(keys[0], (h, d), jnp.float32)
    kc = _rand(keys[1], (h, c, d), jnp.float32)
    vc = _rand(keys[2], (h, c, d), jnp.float32)
    got = decode.decode_attention(q, kc, vc, pos, block_c=block_c)
    want = ref.decode_attention_ref(q, kc, vc, pos)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_decode_attention_pos1_returns_v0():
    # with a single valid cache entry, attention output == v[:, 0, :]
    key = jax.random.PRNGKey(7)
    q = _rand(key, (2, 16), jnp.float32)
    kc = _rand(key, (2, 64, 16), jnp.float32)
    vc = _rand(key, (2, 64, 16), jnp.float32)
    out = decode.decode_attention(q, kc, vc, 1)
    np.testing.assert_allclose(out, vc[:, 0, :], rtol=1e-5, atol=1e-5)


def test_decode_attention_ignores_padding():
    # garbage beyond pos must not change the result
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 3)
    q = _rand(ks[0], (2, 16), jnp.float32)
    kc = _rand(ks[1], (2, 64, 16), jnp.float32)
    vc = _rand(ks[2], (2, 64, 16), jnp.float32)
    pos = 17
    base = decode.decode_attention(q, kc, vc, pos)
    kc2 = kc.at[:, pos:, :].set(1e6)
    vc2 = vc.at[:, pos:, :].set(-1e6)
    got = decode.decode_attention(q, kc2, vc2, pos)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------- ffn

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([16, 32, 64]),
    f_mult=st.sampled_from([2, 4]),
    block_s=st.sampled_from([8, 16, 32]),
)
def test_fused_ffn_matches_ref(seed, s, d, f_mult, block_s):
    if s % min(block_s, s) != 0:
        return
    f = d * f_mult
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(keys[0], (s, d), jnp.float32)
    w1 = _rand(keys[1], (d, f), jnp.float32, 0.3)
    b1 = _rand(keys[2], (f,), jnp.float32, 0.3)
    w2 = _rand(keys[3], (f, d), jnp.float32, 0.3)
    b2 = _rand(keys[4], (d,), jnp.float32, 0.3)
    got = ffn.fused_ffn(x, w1, b1, w2, b2, block_s=block_s)
    want = ref.ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ffn_block_s_one():
    # decode path uses block_s=1
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 5)
    x = _rand(ks[0], (1, 32), jnp.float32)
    w1, b1 = _rand(ks[1], (32, 64), jnp.float32, .3), _rand(ks[2], (64,), jnp.float32, .3)
    w2, b2 = _rand(ks[3], (64, 32), jnp.float32, .3), _rand(ks[4], (32,), jnp.float32, .3)
    got = ffn.fused_ffn(x, w1, b1, w2, b2, block_s=1)
    np.testing.assert_allclose(got, ref.ffn_ref(x, w1, b1, w2, b2), rtol=2e-4, atol=2e-4)


def test_gelu_ref_known_values():
    np.testing.assert_allclose(ref.gelu_ref(jnp.zeros(4)), np.zeros(4), atol=1e-7)
    # GELU(x) -> x for large x, -> 0 for very negative x
    np.testing.assert_allclose(ref.gelu_ref(jnp.array([10.0])), [10.0], rtol=1e-4)
    np.testing.assert_allclose(ref.gelu_ref(jnp.array([-10.0])), [0.0], atol=1e-4)
