"""AOT pipeline: manifest/weights round-trip and HLO re-execution.

Uses a tiny config exported to a tmpdir so the test is hermetic (the real
`make artifacts` output is additionally smoke-checked if present).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

TINY = ["--d-model", "32", "--n-layers", "1", "--n-heads", "2", "--d-ff", "64",
        "--cache-capacity", "32", "--buckets", "8"]


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    rc = aot.main(TINY + ["--out-dir", str(out), "--check"])
    assert rc == 0
    return out


def test_manifest_structure(tiny_artifacts):
    man = json.loads((tiny_artifacts / "manifest.json").read_text())
    assert man["version"] >= 1
    cfg = man["config"]
    assert cfg["d_model"] == 32 and cfg["n_layers"] == 1
    assert "prefill_s8" in man["entrypoints"]
    assert "decode" in man["entrypoints"]
    # weight table offsets are contiguous f32
    off = 0
    for p in man["weights"]["params"]:
        assert p["offset"] == off
        assert p["elems"] == int(np.prod(p["shape"]))
        off += p["elems"] * 4
    assert off == man["weights"]["bytes"]


def test_weights_bin_round_trip(tiny_artifacts):
    man = json.loads((tiny_artifacts / "manifest.json").read_text())
    raw = (tiny_artifacts / "weights.bin").read_bytes()
    assert len(raw) == man["weights"]["bytes"]
    cfg = M.ModelConfig(d_model=32, n_layers=1, n_heads=2, d_ff=64,
                        cache_capacity=32, prefill_buckets=(8,))
    params = M.init_params(jax.random.PRNGKey(man["seed"]), cfg)
    for p, arr in zip(man["weights"]["params"], params):
        got = np.frombuffer(raw, "<f4", count=p["elems"], offset=p["offset"])
        np.testing.assert_array_equal(got.reshape(p["shape"]), np.asarray(arr))


def test_hlo_reexecution_matches_model(tiny_artifacts):
    """Round-trip the exported HLO text through XLA and compare logits."""
    from jax._src.lib import xla_client as xc
    man = json.loads((tiny_artifacts / "manifest.json").read_text())
    cfg = M.ModelConfig(d_model=32, n_layers=1, n_heads=2, d_ff=64,
                        cache_capacity=32, prefill_buckets=(8,))
    params = M.init_params(jax.random.PRNGKey(man["seed"]), cfg)
    hlo_text = (tiny_artifacts / "prefill_s8.hlo.txt").read_text()
    # parse HLO text back and execute on the CPU client
    client = xc._xla.get_tfrt_cpu_client()  # type: ignore[attr-defined]
    comp = xc._xla.hlo_module_from_text(hlo_text)
    toks = (jnp.arange(8, dtype=jnp.int32) * 37 + 11) % cfg.vocab
    want, _, _ = M.prefill(params, toks, cfg)
    try:
        xla_comp = xc.XlaComputation(comp.as_serialized_hlo_module_proto())
        exe = client.compile(xla_comp.as_serialized_hlo_module_proto())
        args = [np.asarray(a) for a in params] + [np.asarray(toks)]
        bufs = [client.buffer_from_pyval(a) for a in args]
        out = exe.execute(bufs)
        got = np.asarray(out[0])
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-4, atol=1e-4)
    except Exception as e:  # pragma: no cover - API drift across jaxlibs
        pytest.skip(f"python-side HLO re-execution unsupported here: {e}; "
                    f"rust integration tests cover this path")


def test_entrypoint_specs(tiny_artifacts):
    man = json.loads((tiny_artifacts / "manifest.json").read_text())
    pre = man["entrypoints"]["prefill_s8"]
    assert pre["extra_inputs"] == [{"shape": [8], "dtype": "int32"}]
    dec = man["entrypoints"]["decode"]
    # packed state, pos, token
    assert len(dec["extra_inputs"]) == 3
    assert dec["extra_inputs"][0]["shape"] == [man["config"]["packed_len"]]
    assert dec["extra_inputs"][1]["shape"] == [1]
    assert dec["extra_inputs"][2]["dtype"] == "int32"
    assert "logits" in man["entrypoints"]


def test_real_artifacts_if_present():
    """Smoke-check the `make artifacts` output this repo actually ships."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(adir, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("run `make artifacts` first")
    man = json.loads(open(man_path).read())
    for name, ep in man["entrypoints"].items():
        path = os.path.join(adir, ep["file"])
        assert os.path.exists(path), f"missing {path}"
        head = open(path).read(200)
        assert "HloModule" in head
    wsize = os.path.getsize(os.path.join(adir, "weights.bin"))
    assert wsize == man["weights"]["bytes"]
