"""Extension kernels: weight-only int8 matmul + RMSNorm vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import norm, quant, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=20, deadline=None)


# --------------------------------------------------------------- quantize

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([16, 32, 64]),
    n=st.sampled_from([64, 128]),
)
def test_quantize_round_trip_error_bounded(seed, k, n):
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (k, n), jnp.float32)
    w_q, scale = quant.quantize_per_channel(w)
    assert w_q.dtype == jnp.int8
    deq = w_q.astype(jnp.float32) * scale[None, :]
    # per-channel absmax quantization: error ≤ scale/2 per element
    err = jnp.abs(deq - w)
    assert bool(jnp.all(err <= scale[None, :] * 0.5 + 1e-6))


def test_quantize_zero_column_safe():
    w = jnp.zeros((8, 4))
    w_q, scale = quant.quantize_per_channel(w)
    np.testing.assert_array_equal(np.asarray(w_q), 0)
    assert bool(jnp.all(scale > 0))  # no div-by-zero scales


# ---------------------------------------------------------------- qmatmul

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.sampled_from([8, 32, 64]),
    k=st.sampled_from([16, 64]),
    n=st.sampled_from([64, 128]),
    block_s=st.sampled_from([8, 32]),
    block_n=st.sampled_from([32, 64]),
)
def test_quantized_matmul_matches_ref(seed, s, k, n, block_s, block_n):
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (s, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32)
    w_q, scale = quant.quantize_per_channel(w)
    got = quant.quantized_matmul(x, w_q, scale, block_s=block_s, block_n=block_n)
    want = quant.quantized_matmul_ref(x, w_q, scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_quantized_matmul_close_to_fp32():
    # end-to-end quantization error vs the unquantized matmul stays small
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(keys[0], (32, 64), jnp.float32)
    w = jax.random.normal(keys[1], (64, 128), jnp.float32)
    w_q, scale = quant.quantize_per_channel(w)
    got = quant.quantized_matmul(x, w_q, scale)
    exact = x @ w
    rel = float(jnp.linalg.norm(got - exact) / jnp.linalg.norm(exact))
    assert rel < 0.01, f"int8 relative error {rel}"


def test_quantized_matmul_rejects_mismatch():
    import pytest
    x = jnp.zeros((8, 16))
    w_q = jnp.zeros((32, 64), jnp.int8)
    with pytest.raises(ValueError):
        quant.quantized_matmul(x, w_q, jnp.ones((64,)))


# ---------------------------------------------------------------- rmsnorm

@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.sampled_from([8, 32, 64]),
    d=st.sampled_from([16, 64, 128]),
    block_s=st.sampled_from([8, 16, 32]),
)
def test_rmsnorm_matches_ref(seed, s, d, block_s):
    if s % min(block_s, s) != 0:
        return
    keys = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(keys[0], (s, d), jnp.float32) * 3.0
    g = jax.random.normal(keys[1], (d,), jnp.float32)
    got = norm.rmsnorm(x, g, block_s=block_s)
    want = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rmsnorm_unit_output_scale():
    # with g = 1, output rows have RMS ≈ 1
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 256), jnp.float32) * 10.0
    out = norm.rmsnorm(x, jnp.ones(256))
    rms = jnp.sqrt(jnp.mean(out * out, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)
