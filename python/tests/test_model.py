"""L2 correctness: model invariants (kernel path vs pure-jnp oracle,
decode/prefill consistency, causality, cache semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(d_model=64, n_layers=2, n_heads=4, d_ff=128,
                    cache_capacity=64, prefill_buckets=(8, 16))


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def _toks(n, seed=0):
    return ((jnp.arange(n) * 37 + 11 + seed) % CFG.vocab).astype(jnp.int32)


def test_param_count_matches_shapes():
    shapes = M.param_shapes(CFG)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == CFG.param_count()


def test_param_names_unique_and_cover_shapes():
    names = M.param_names(CFG)
    assert len(names) == len(set(names))
    assert set(names) == set(M.param_shapes(CFG).keys())


def test_prefill_kernel_path_matches_ref(params):
    toks = _toks(16)
    lg, kc, vc = M.prefill(params, toks, CFG)
    lg2, kc2, vc2 = M.prefill_ref(params, toks, CFG)
    np.testing.assert_allclose(lg, lg2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(kc, kc2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(vc, vc2, rtol=1e-4, atol=1e-4)


def test_prefill_cache_padding_is_zero(params):
    toks = _toks(8)
    _, kc, vc = M.prefill(params, toks, CFG)
    assert kc.shape == (CFG.n_layers, CFG.n_heads, CFG.cache_capacity, CFG.d_head)
    np.testing.assert_array_equal(np.asarray(kc[:, :, 8:, :]), 0.0)
    np.testing.assert_array_equal(np.asarray(vc[:, :, 8:, :]), 0.0)


def test_decode_consistent_with_prefill(params):
    """decode_step after prefill(S) must equal prefill(S+1)'s last logits."""
    toks = _toks(8)
    lg, kc, vc = M.prefill(params, toks, CFG)
    nxt = jnp.int32(42)
    lg_d, kc_d, vc_d = M.decode_step(params, kc, vc, 8, nxt, CFG)
    lg_p, kc_p, vc_p = M.prefill_ref(params, jnp.concatenate([toks, nxt[None]]), CFG)
    np.testing.assert_allclose(lg_d, lg_p, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(kc_d[:, :, :9], kc_p[:, :, :9], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(vc_d[:, :, :9], vc_p[:, :, :9], rtol=1e-3, atol=1e-3)


def test_decode_chain_matches_full_prefill(params):
    """3 chained decode steps == prefill over the extended sequence."""
    toks = _toks(8, seed=3)
    _, kc, vc = M.prefill(params, toks, CFG)
    extra = [5, 200, 133]
    pos = 8
    for t in extra:
        lg, kc, vc = M.decode_step(params, kc, vc, pos, jnp.int32(t), CFG)
        pos += 1
    full = jnp.concatenate([toks, jnp.array(extra, jnp.int32)])
    lg_full, _, _ = M.prefill_ref(params, full, CFG)
    np.testing.assert_allclose(lg, lg_full, rtol=1e-3, atol=1e-3)


def test_causality(params):
    """Changing a later token must not affect an earlier prefix's cache."""
    t1 = _toks(16)
    t2 = t1.at[12].set((int(t1[12]) + 7) % 256)
    _, k1, _ = M.prefill(params, t1, CFG)
    _, k2, _ = M.prefill(params, t2, CFG)
    np.testing.assert_allclose(k1[:, :, :12], k2[:, :, :12], rtol=1e-5, atol=1e-5)
    assert not np.allclose(k1[:, :, 12], k2[:, :, 12])


def test_logits_finite(params):
    lg, _, _ = M.prefill(params, _toks(16), CFG)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert lg.shape == (CFG.vocab,)


def test_generate_ref_deterministic(params):
    out1 = M.generate_ref(params, _toks(8), 4, CFG)
    out2 = M.generate_ref(params, _toks(8), 4, CFG)
    assert out1 == out2
    assert all(0 <= t < CFG.vocab for t in out1)


def test_rope_position_dependence():
    x = jnp.ones((4, 2, 8))
    p0 = M._rope(x, jnp.array([0, 0, 0, 0], jnp.int32))
    p1 = M._rope(x, jnp.array([0, 1, 2, 3], jnp.int32))
    np.testing.assert_allclose(p0[0], p1[0], atol=1e-6)
    assert not np.allclose(p0[1], p1[1])


def test_rope_norm_preserving():
    # rotations preserve the per-pair L2 norm
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (6, 3, 16))
    y = M._rope(x, jnp.arange(6, dtype=jnp.int32))
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1),
                               rtol=1e-5, atol=1e-5)
