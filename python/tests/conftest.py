import os
import sys

# Make `compile` importable when pytest is run from python/ or repo root.
sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
