"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here. pytest compares kernel vs. oracle across a hypothesis
sweep of shapes/dtypes/seeds — this is the core L1 correctness signal.
"""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Plain softmax attention.

    q: (S, D), k: (T, D), v: (T, D)  →  (S, D)
    Causal masking assumes query position i attends to key positions
    <= i + (T - S)  (i.e. q is the *suffix* of a length-T context).
    """
    s, d = q.shape
    t = k.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if causal:
        qpos = jnp.arange(s)[:, None] + (t - s)
        kpos = jnp.arange(t)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return (w @ v.astype(jnp.float32)).astype(q.dtype)


def mha_ref(q, k, v, *, causal: bool = True):
    """Multi-head attention over (H, S, D) tensors via vmap of attention_ref."""
    return jax.vmap(lambda qq, kk, vv: attention_ref(qq, kk, vv, causal=causal))(q, k, v)


def decode_attention_ref(q, k_cache, v_cache, pos):
    """Single-token decode attention against a (padded) KV cache.

    q: (H, D) one query token per head; k_cache/v_cache: (H, C, D) padded
    to capacity C; pos: scalar int — number of valid cache entries
    *including* the current token's K/V (already written at index pos-1).
    Returns (H, D).
    """
    h, c, d = k_cache.shape
    scale = 1.0 / jnp.sqrt(jnp.array(d, dtype=jnp.float32))
    logits = jnp.einsum("hd,hcd->hc", q.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(c)[None, :] < pos
    logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hc,hcd->hd", w, v_cache.astype(jnp.float32)).astype(q.dtype)


def gelu_ref(x):
    """tanh-approximation GELU (matches the kernel's polynomial)."""
    xf = x.astype(jnp.float32)
    c = jnp.sqrt(jnp.array(2.0 / jnp.pi, dtype=jnp.float32))
    out = 0.5 * xf * (1.0 + jnp.tanh(c * (xf + 0.044715 * xf**3)))
    return out.astype(x.dtype)


def ffn_ref(x, w1, b1, w2, b2):
    """Fused position-wise FFN: GELU(x @ w1 + b1) @ w2 + b2.

    x: (S, D), w1: (D, F), w2: (F, D)  →  (S, D)
    """
    h = gelu_ref(x.astype(jnp.float32) @ w1.astype(jnp.float32) + b1.astype(jnp.float32))
    out = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return out.astype(x.dtype)


def rmsnorm_ref(x, g, eps: float = 1e-5):
    """RMSNorm over the last axis."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * g.astype(jnp.float32)).astype(x.dtype)
