"""Pallas fused prefill attention (flash-style online softmax).

TPU-shaped structure (see DESIGN.md §Hardware-Adaptation): the grid walks
(q_block, k_block) tiles; each step pulls a (BQ, D) query tile and a
(BK, D) key/value tile from HBM into VMEM, runs the (BQ×D)·(D×BK) matmul
chain on the MXU in fp32 accumulate, and maintains the online-softmax
running max `m`, denominator `l`, and output accumulator in VMEM scratch.
Nothing of size (S, T) ever materializes.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU numbers are estimated analytically
(EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32

NEG_INF = -1e30  # large-negative instead of -inf: keeps exp() well-defined


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale, causal, block_q, block_k, num_kb, t_minus_s):
    """One (q_block, k_block) grid step of online-softmax attention."""
    qb = pl.program_id(0)
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)           # (BQ, D)
    k = k_ref[...].astype(jnp.float32)           # (BK, D)
    v = v_ref[...].astype(jnp.float32)           # (BK, D)

    s = jnp.dot(q, k.T) * scale                  # (BQ, BK) on the MXU

    if causal:
        # query i (global) attends to key j (global) iff j <= i + (T - S)
        qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(kpos <= qpos + t_minus_s, s, NEG_INF)

    m_prev = m_scr[...]                          # (BQ, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)              # rescale factor for old state
    p = jnp.exp(s - m_new)                       # (BQ, BK)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)

    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kb == num_kb - 1)
    def _finalize():
        # Fully-masked rows (can't happen for causal suffix layouts, but
        # guard anyway): l == 0 → emit zeros rather than NaN.
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True):
    """Single-head flash attention. q: (S, D), k/v: (T, D) → (S, D).

    S must be divisible by block_q and T by block_k (callers pad to the
    bucket sizes the AOT pipeline exports).
    """
    s, d = q.shape
    t = k.shape[0]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    if s % block_q != 0 or t % block_k != 0:
        raise ValueError(f"shape ({s},{t}) not divisible by blocks ({block_q},{block_k})")
    num_qb = s // block_q
    num_kb = t // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_kb=num_kb, t_minus_s=t - s)

    return pl.pallas_call(
        kernel,
        grid=(num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), q.dtype),
        scratch_shapes=[
            # (BQ, 1) running max / denominator + (BQ, D) accumulator, VMEM
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def mha_flash(q, k, v, *, causal: bool = True, interpret: bool = True,
              block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K):
    """Multi-head prefill attention. q/k/v: (H, S|T, D) → (H, S, D)."""
    fn = functools.partial(flash_attention, causal=causal, interpret=interpret,
                           block_q=block_q, block_k=block_k)
    return jax.vmap(fn)(q, k, v)
