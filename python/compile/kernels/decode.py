"""Pallas decode-step attention: one query token vs. a padded KV cache.

Decode attention is bandwidth-bound (the paper's Fig. 2 asymmetry comes
from exactly this: every generated token re-streams the whole KV cache).
The kernel walks (head, cache_block) grid steps, streaming (BC, D) cache
tiles HBM→VMEM and reducing with an online softmax held in VMEM scratch —
the (C,)-sized logit row never materializes in HBM.

The cache is padded to capacity C; `pos` (an int32 scalar, passed as a
(1, 1) array so interpret mode is happy) marks how many entries are
valid, *including* the current token's K/V already written at pos-1.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import attention as _attn

DEFAULT_BLOCK_C = 64
NEG_INF = _attn.NEG_INF


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, block_c, num_cb):
    cb = pl.program_id(1)  # cache-block index; program_id(0) is the head

    @pl.when(cb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0, 0]
    q = q_ref[0].astype(jnp.float32)              # (1, D)
    k = k_ref[0].astype(jnp.float32)              # (BC, D)
    v = v_ref[0].astype(jnp.float32)              # (BC, D)

    s = jnp.dot(q, k.T) * scale                   # (1, BC)
    cpos = cb * block_c + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    s = jnp.where(cpos < pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jnp.dot(p, v)
    m_scr[...] = m_new

    @pl.when(cb == num_cb - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, pos, *,
                     block_c: int = DEFAULT_BLOCK_C,
                     interpret: bool = True):
    """q: (H, D), k_cache/v_cache: (H, C, D), pos: int32 scalar → (H, D)."""
    h, c, d = k_cache.shape
    block_c = min(block_c, c)
    if c % block_c != 0:
        raise ValueError(f"cache capacity {c} not divisible by block {block_c}")
    num_cb = c // block_c
    scale = 1.0 / (d ** 0.5)

    pos_arr = jnp.asarray(pos, dtype=jnp.int32).reshape(1, 1)
    q2 = q.reshape(h, 1, d)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_c=block_c, num_cb=num_cb)

    out = pl.pallas_call(
        kernel,
        grid=(h, num_cb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),        # pos (replicated)
            pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),  # q row for head i
            pl.BlockSpec((1, block_c, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, d), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q2, k_cache, v_cache)
    return out.reshape(h, d)
