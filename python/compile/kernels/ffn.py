"""Pallas fused position-wise FFN: GELU(x @ W1 + b1) @ W2 + b2.

Fusion keeps the (BS, F) intermediate in VMEM — on real hardware the
(S, F) activation (4× the model width) never round-trips to HBM, which is
the whole point of fusing the block. Grid walks S in (BS,)-row tiles;
weights are small enough (D×F + F×D) to be resident per grid step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 32


def _gelu(x):
    c = jnp.sqrt(jnp.array(2.0 / jnp.pi, dtype=jnp.float32))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)            # (BS, D)
    h = _gelu(jnp.dot(x, w1_ref[...].astype(jnp.float32))
              + b1_ref[...].astype(jnp.float32))  # (BS, F) stays in VMEM
    out = jnp.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def fused_ffn(x, w1, b1, w2, b2, *, block_s: int = DEFAULT_BLOCK_S,
              interpret: bool = True):
    """x: (S, D), w1: (D, F), b1: (F,), w2: (F, D), b2: (D,) → (S, D)."""
    s, d = x.shape
    f = w1.shape[1]
    block_s = min(block_s, s)
    if s % block_s != 0:
        raise ValueError(f"seq len {s} not divisible by block {block_s}")

    return pl.pallas_call(
        _ffn_kernel,
        grid=(s // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_s, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=interpret,
    )(x, w1, b1, w2, b2)
