"""Pallas weight-only int8 matmul kernel (dequantize-in-kernel).

Serving-stack extension: the paper's related work (§7.1, LLM-PQ) serves
heterogeneous clusters with adaptive quantization; the V100's OOMs in
§5.3–5.4 are exactly what weight-only int8 fixes (7B fp16 = 13.4 GB →
int8 = 6.7 GB, inside a 16 GB card with room for KV). This kernel is the
compute primitive for that mode: weights stay int8 in HBM and are
dequantized tile-by-tile in VMEM, halving the bandwidth per decode step.

y = x @ (w_q.astype(f32) * scale[col])      x: (S, K) f32
                                            w_q: (K, N) int8
                                            scale: (N,) f32 per-channel
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 32
DEFAULT_BLOCK_N = 64


def quantize_per_channel(w):
    """fp32 (K, N) → (int8 (K, N), f32 scale (N,)) per output channel."""
    absmax = jnp.max(jnp.abs(w), axis=0)                  # (N,)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    w_q = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return w_q, scale.astype(jnp.float32)


def _qmatmul_kernel(x_ref, wq_ref, scale_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                    # (BS, K)
    w = wq_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = jnp.dot(x, w).astype(o_ref.dtype)        # (BS, BN)


def quantized_matmul(x, w_q, scale, *, block_s: int = DEFAULT_BLOCK_S,
                     block_n: int = DEFAULT_BLOCK_N, interpret: bool = True):
    """x: (S, K) f32, w_q: (K, N) int8, scale: (N,) f32 → (S, N) f32."""
    s, k = x.shape
    k2, n = w_q.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    block_s = min(block_s, s)
    block_n = min(block_n, n)
    if s % block_s != 0 or n % block_n != 0:
        raise ValueError(f"shape ({s},{n}) not divisible by blocks ({block_s},{block_n})")

    return pl.pallas_call(
        functools.partial(_qmatmul_kernel),
        grid=(s // block_s, n // block_n),
        in_specs=[
            pl.BlockSpec((block_s, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((block_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_s, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        interpret=interpret,
    )(x, w_q, scale)


def quantized_matmul_ref(x, w_q, scale):
    """Oracle: dequantize fully, then matmul."""
    w = w_q.astype(jnp.float32) * scale[None, :]
    return x.astype(jnp.float32) @ w
