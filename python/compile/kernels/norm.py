"""Pallas RMSNorm kernel (row-tiled, single HBM pass).

RMSNorm appears 2L+1 times per forward; fusing the mean-square reduction
with the scale keeps each row's activation in VMEM for exactly one read
and one write. Matches `ref.rmsnorm_ref` bit-for-bit up to fp tolerance.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 32


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                    # (BS, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm(x, g, *, eps: float = 1e-5, block_s: int = DEFAULT_BLOCK_S,
            interpret: bool = True):
    """x: (S, D), g: (D,) → (S, D)."""
    s, d = x.shape
    block_s = min(block_s, s)
    if s % block_s != 0:
        raise ValueError(f"seq len {s} not divisible by block {block_s}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(s // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_s, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, d), x.dtype),
        interpret=interpret,
    )(x, g)
