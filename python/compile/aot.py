"""AOT pipeline: lower the L2 model to HLO text + dump weights.

Run once at build time (`make artifacts`); the rust runtime is then fully
self-contained. Interchange is **HLO text**, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 (behind the published `xla` 0.1.6 crate) rejects with
`proto.id() <= INT_MAX`; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  prefill_s{S}.hlo.txt   one per sequence-length bucket
  decode.hlo.txt         single-token decode step
  weights.bin            f32 little-endian, concatenated in param order
  manifest.json          config + param table (name/shape/offset) +
                         entrypoint descriptions, consumed by
                         rust/src/runtime/artifacts.rs

Argument convention (must match rust/src/runtime/engine.rs):
  prefill_sS : [*params, tokens(S,i32)] -> (logits(V,), k(L,H,C,Dh), v(...))
  decode     : [*params, k, v, pos(1,i32), token(1,i32)]
                 -> (logits(V,), k, v)
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (ids reassigned).

    return_tuple=False: PJRT then hands the rust runtime one buffer per
    output (logits, k, v), which lets decode steps chain KV caches as
    device buffers via execute_b with no per-step host round-trip — the
    §Perf optimization recorded in EXPERIMENTS.md.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def packed_len(cfg: M.ModelConfig) -> int:
    """Flat state layout: [logits (V) | k (L·H·C·Dh) | v (L·H·C·Dh)].

    Packing the whole step state into ONE array keeps the HLO root a
    plain array (multi-result modules get a tuple root, which this
    PJRT stack returns as a single un-splittable tuple buffer). A single
    array output chains across decode steps as a device buffer; the tiny
    `logits` slicer below is the only per-step host transfer (~1 KB).
    """
    cache = cfg.n_layers * cfg.n_heads * cfg.cache_capacity * cfg.d_head
    return cfg.vocab + 2 * cache


def _pack(logits, k, v, cfg):
    return jnp.concatenate([logits, k.reshape(-1), v.reshape(-1)])


def _unpack_caches(packed, cfg):
    l, h, c, dh, v = (cfg.n_layers, cfg.n_heads, cfg.cache_capacity,
                      cfg.d_head, cfg.vocab)
    cache = l * h * c * dh
    k = packed[v:v + cache].reshape(l, h, c, dh)
    vv = packed[v + cache:v + 2 * cache].reshape(l, h, c, dh)
    return k, vv


def build_entrypoints(cfg: M.ModelConfig):
    """Return {name: (fn, example_arg_specs)} for every HLO we export."""
    c = cfg.cache_capacity
    shapes = M.param_shapes(cfg)
    pspecs = [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in M.param_names(cfg)]
    packed_spec = jax.ShapeDtypeStruct((packed_len(cfg),), jnp.float32)
    i1 = jax.ShapeDtypeStruct((1,), jnp.int32)

    entries = {}

    def make_prefill(s):
        def fn(*args):
            params = list(args[:-1])
            tokens = args[-1]
            logits, k, v = M.prefill(params, tokens, cfg)
            return _pack(logits, k, v, cfg)
        return fn, pspecs + [jax.ShapeDtypeStruct((s,), jnp.int32)]

    for s in cfg.prefill_buckets:
        if s > c:
            raise ValueError(f"bucket {s} exceeds cache capacity {c}")
        entries[f"prefill_s{s}"] = make_prefill(s)

    def decode_fn(*args):
        params = list(args[:-3])
        packed, pos, token = args[-3:]
        k_cache, v_cache = _unpack_caches(packed, cfg)
        logits, k, v = M.decode_step(params, k_cache, v_cache, pos[0], token[0], cfg)
        return _pack(logits, k, v, cfg)

    entries["decode"] = (decode_fn, pspecs + [packed_spec, i1, i1])

    def logits_fn(packed):
        return packed[: cfg.vocab]

    entries["logits"] = (logits_fn, [packed_spec])
    return entries


def write_weights(params, cfg: M.ModelConfig, out_dir: str):
    """weights.bin (f32 LE) + the manifest param table."""
    table = []
    offset = 0
    path = os.path.join(out_dir, "weights.bin")
    with open(path, "wb") as f:
        for name, arr in zip(M.param_names(cfg), params):
            a = np.asarray(arr, dtype="<f4")
            f.write(a.tobytes())
            table.append({
                "name": name,
                "shape": list(a.shape),
                "offset": offset,
                "elems": int(a.size),
            })
            offset += a.size * 4
    return table, offset


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--out", default=None, help="(Makefile stamp) path of the stamp HLO file")
    ap.add_argument("--seed", type=int, default=20240603)  # E2DC'24 date
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-layers", type=int, default=4)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--cache-capacity", type=int, default=512)
    ap.add_argument("--buckets", default="8,16,32,64,128,256")
    ap.add_argument("--check", action="store_true", help="numeric self-test after export")
    args = ap.parse_args(argv)

    cfg = M.ModelConfig(
        d_model=args.d_model, n_layers=args.n_layers, n_heads=args.n_heads,
        d_ff=args.d_ff, cache_capacity=args.cache_capacity,
        prefill_buckets=tuple(int(b) for b in args.buckets.split(",")),
    )
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] model: {cfg.param_count():,} params, buckets={cfg.prefill_buckets}, "
          f"capacity={cfg.cache_capacity}", flush=True)

    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    table, nbytes = write_weights(params, cfg, out_dir)
    print(f"[aot] weights.bin: {nbytes/1e6:.2f} MB", flush=True)

    entrypoints = {}
    for name, (fn, specs) in build_entrypoints(cfg).items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entrypoints[name] = {
            "file": fname,
            "num_params": len(M.param_names(cfg)),
            "extra_inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in specs[len(M.param_names(cfg)):]
            ],
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"[aot] {fname}: {len(text)/1e6:.2f} MB HLO text", flush=True)

    manifest = {
        "version": 2,  # v2: untupled outputs (one PJRT buffer per output)
        "seed": args.seed,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_head": cfg.d_head, "d_ff": cfg.d_ff,
            "cache_capacity": cfg.cache_capacity,
            "prefill_buckets": list(cfg.prefill_buckets),
            "param_count": cfg.param_count(),
            "packed_len": packed_len(cfg),
        },
        "weights": {"file": "weights.bin", "bytes": nbytes, "params": table},
        "entrypoints": entrypoints,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written to {out_dir}", flush=True)

    if args.check:
        _self_check(params, cfg)
    return 0


def _self_check(params, cfg):
    """Round-trip numeric check: jitted export fns == direct model calls."""
    s = cfg.prefill_buckets[0]
    toks = (jnp.arange(s, dtype=jnp.int32) * 37 + 11) % cfg.vocab
    eps = build_entrypoints(cfg)
    fn, _ = eps[f"prefill_s{s}"]
    packed = jax.jit(fn)(*params, toks)
    lg, kc, vc = M.prefill(params, toks, cfg)
    lfn, _ = eps["logits"]
    np.testing.assert_allclose(jax.jit(lfn)(packed), lg, rtol=1e-4, atol=1e-4)
    k_got, v_got = _unpack_caches(packed, cfg)
    np.testing.assert_allclose(k_got, kc, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(v_got, vc, rtol=1e-4, atol=1e-4)
    dfn, _ = eps["decode"]
    tok = int(jnp.argmax(lg))
    packed_d = jax.jit(dfn)(*params, packed, jnp.array([s], jnp.int32),
                            jnp.array([tok], jnp.int32))
    want_d = M.decode_step(params, kc, vc, s, tok, cfg)
    np.testing.assert_allclose(jax.jit(lfn)(packed_d), want_d[0], rtol=1e-4, atol=1e-4)
    print("[aot] self-check OK", flush=True)


if __name__ == "__main__":
    sys.exit(main())
