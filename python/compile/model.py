"""L2: byte-level transformer LM (pure JAX, calling the L1 Pallas kernels).

This is the model the rust coordinator actually *serves* end-to-end: a
small GPT-style decoder with RoPE, RMSNorm, flash prefill attention, a
Pallas decode-step attention against an explicit KV cache, and a fused
Pallas FFN. Python never runs at request time — `aot.py` lowers
``prefill`` (one HLO per sequence-length bucket) and ``decode_step`` (one
HLO) to text that `rust/src/runtime` loads via PJRT.

Everything is purely functional: the KV cache is an explicit input and
output, so the rust side owns cache state between steps.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import decode as decode_k
from .kernels import ffn as ffn_k
from .kernels import ref as ref_k


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for the served model."""
    vocab: int = 256          # byte-level: token == byte; 0 doubles as BOS
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    cache_capacity: int = 512  # max context (prefill + generated)
    prefill_buckets: tuple = (8, 16, 32, 64, 128, 256)
    dtype: str = "float32"

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        d, f, v, l = self.d_model, self.d_ff, self.vocab, self.n_layers
        per_layer = 4 * d * d + 2 * d * f + f + d + 2 * d  # qkvo + ffn + norms
        return v * d + l * per_layer + d + v * d  # embed + layers + final norm + unembed


# Deterministic parameter ordering: rust's artifact loader feeds literals
# in exactly this sequence (see aot.py manifest).
def param_names(cfg: ModelConfig) -> list[str]:
    names = ["embed"]
    for i in range(cfg.n_layers):
        names += [
            f"layer{i}.ln1", f"layer{i}.wq", f"layer{i}.wk", f"layer{i}.wv",
            f"layer{i}.wo", f"layer{i}.ln2", f"layer{i}.w1", f"layer{i}.b1",
            f"layer{i}.w2", f"layer{i}.b2",
        ]
    names += ["ln_f", "unembed"]
    return names


def param_shapes(cfg: ModelConfig) -> dict[str, tuple]:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    shapes = {"embed": (v, d), "ln_f": (d,), "unembed": (d, v)}
    for i in range(cfg.n_layers):
        shapes[f"layer{i}.ln1"] = (d,)
        shapes[f"layer{i}.wq"] = (d, d)
        shapes[f"layer{i}.wk"] = (d, d)
        shapes[f"layer{i}.wv"] = (d, d)
        shapes[f"layer{i}.wo"] = (d, d)
        shapes[f"layer{i}.ln2"] = (d,)
        shapes[f"layer{i}.w1"] = (d, f)
        shapes[f"layer{i}.b1"] = (f,)
        shapes[f"layer{i}.w2"] = (f, d)
        shapes[f"layer{i}.b2"] = (d,)
    return shapes


def init_params(key, cfg: ModelConfig) -> list[jnp.ndarray]:
    """Scaled-normal init; returns params as a flat list in param_names order."""
    shapes = param_shapes(cfg)
    names = param_names(cfg)
    keys = jax.random.split(key, len(names))
    out = []
    for k, name in zip(keys, names):
        shape = shapes[name]
        if name.endswith((".ln1", ".ln2")) or name == "ln_f":
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b1", ".b2")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            out.append(jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in))
    return out


def _rope(x, positions):
    """Rotary position embedding. x: (S, H, Dh), positions: (S,) int32."""
    s, h, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]   # (S, half)
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _unpack(params: list, cfg: ModelConfig) -> dict[str, jnp.ndarray]:
    return dict(zip(param_names(cfg), params))


def prefill(params: list, tokens, cfg: ModelConfig, *, interpret: bool = True):
    """Process a full prompt. tokens: (S,) int32.

    Returns (logits_last (vocab,), k_cache, v_cache) with caches shaped
    (L, H, C, Dh), the first S rows valid.
    """
    p = _unpack(params, cfg)
    s = tokens.shape[0]
    h, dh, c = cfg.n_heads, cfg.d_head, cfg.cache_capacity
    positions = jnp.arange(s, dtype=jnp.int32)

    x = p["embed"][tokens]                       # (S, D)
    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        xn = ref_k.rmsnorm_ref(x, p[f"layer{i}.ln1"])
        q = (xn @ p[f"layer{i}.wq"]).reshape(s, h, dh)
        k = (xn @ p[f"layer{i}.wk"]).reshape(s, h, dh)
        v = (xn @ p[f"layer{i}.wv"]).reshape(s, h, dh)
        q = _rope(q, positions)
        k = _rope(k, positions)
        # L1 kernel: flash attention over (H, S, Dh)
        o = attn_k.mha_flash(q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                             v.transpose(1, 0, 2), causal=True,
                             interpret=interpret)
        o = o.transpose(1, 0, 2).reshape(s, cfg.d_model)
        x = x + o @ p[f"layer{i}.wo"]
        xn2 = ref_k.rmsnorm_ref(x, p[f"layer{i}.ln2"])
        # L1 kernel: fused FFN
        x = x + ffn_k.fused_ffn(xn2, p[f"layer{i}.w1"], p[f"layer{i}.b1"],
                                p[f"layer{i}.w2"], p[f"layer{i}.b2"],
                                interpret=interpret)
        pad = [(0, 0), (0, c - s), (0, 0)]
        k_caches.append(jnp.pad(k.transpose(1, 0, 2), pad))   # (H, C, Dh)
        v_caches.append(jnp.pad(v.transpose(1, 0, 2), pad))

    xf = ref_k.rmsnorm_ref(x, p["ln_f"])
    logits = xf[-1] @ p["unembed"]               # only the last position's logits
    return logits, jnp.stack(k_caches), jnp.stack(v_caches)


def decode_step(params: list, k_cache, v_cache, pos, token, cfg: ModelConfig,
                *, interpret: bool = True):
    """One autoregressive step.

    k_cache/v_cache: (L, H, C, Dh) with `pos` valid entries; `token` is the
    token at position `pos` (int32 scalar). Returns
    (logits (vocab,), new_k_cache, new_v_cache) with pos+1 valid entries.
    """
    p = _unpack(params, cfg)
    h, dh = cfg.n_heads, cfg.d_head
    pos = jnp.asarray(pos, jnp.int32)
    position = pos.reshape(1)

    x = p["embed"][token].reshape(1, cfg.d_model)
    new_k, new_v = [], []
    for i in range(cfg.n_layers):
        xn = ref_k.rmsnorm_ref(x, p[f"layer{i}.ln1"])
        q = (xn @ p[f"layer{i}.wq"]).reshape(1, h, dh)
        k = (xn @ p[f"layer{i}.wk"]).reshape(1, h, dh)
        v = (xn @ p[f"layer{i}.wv"]).reshape(1, h, dh)
        q = _rope(q, position)[0]                # (H, Dh)
        k = _rope(k, position)[0]
        v = v[0]
        # Write this token's K/V at index `pos` along the cache axis.
        kc = jax.lax.dynamic_update_slice(k_cache[i], k[:, None, :], (0, pos, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[i], v[:, None, :], (0, pos, 0))
        # L1 kernel: decode attention against pos+1 valid entries
        o = decode_k.decode_attention(q, kc, vc, pos + 1, interpret=interpret)
        x = x + o.reshape(1, cfg.d_model) @ p[f"layer{i}.wo"]
        xn2 = ref_k.rmsnorm_ref(x, p[f"layer{i}.ln2"])
        x = x + ffn_k.fused_ffn(xn2, p[f"layer{i}.w1"], p[f"layer{i}.b1"],
                                p[f"layer{i}.w2"], p[f"layer{i}.b2"],
                                block_s=1, interpret=interpret)
        new_k.append(kc)
        new_v.append(vc)

    xf = ref_k.rmsnorm_ref(x, p["ln_f"])
    logits = (xf @ p["unembed"]).reshape(-1)
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def prefill_ref(params, tokens, cfg: ModelConfig):
    """Oracle: same network with pure-jnp attention/FFN (no Pallas)."""
    p = _unpack(params, cfg)
    s = tokens.shape[0]
    h, dh, c = cfg.n_heads, cfg.d_head, cfg.cache_capacity
    positions = jnp.arange(s, dtype=jnp.int32)
    x = p["embed"][tokens]
    k_caches, v_caches = [], []
    for i in range(cfg.n_layers):
        xn = ref_k.rmsnorm_ref(x, p[f"layer{i}.ln1"])
        q = _rope((xn @ p[f"layer{i}.wq"]).reshape(s, h, dh), positions)
        k = _rope((xn @ p[f"layer{i}.wk"]).reshape(s, h, dh), positions)
        v = (xn @ p[f"layer{i}.wv"]).reshape(s, h, dh)
        o = ref_k.mha_ref(q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                          v.transpose(1, 0, 2), causal=True)
        x = x + o.transpose(1, 0, 2).reshape(s, cfg.d_model) @ p[f"layer{i}.wo"]
        xn2 = ref_k.rmsnorm_ref(x, p[f"layer{i}.ln2"])
        x = x + ref_k.ffn_ref(xn2, p[f"layer{i}.w1"], p[f"layer{i}.b1"],
                              p[f"layer{i}.w2"], p[f"layer{i}.b2"])
        pad = [(0, 0), (0, c - s), (0, 0)]
        k_caches.append(jnp.pad(k.transpose(1, 0, 2), pad))
        v_caches.append(jnp.pad(v.transpose(1, 0, 2), pad))
    xf = ref_k.rmsnorm_ref(x, p["ln_f"])
    return xf[-1] @ p["unembed"], jnp.stack(k_caches), jnp.stack(v_caches)


def generate_ref(params, prompt, n_out, cfg: ModelConfig):
    """Pure-python greedy generation loop (slow; test oracle only)."""
    logits, kc, vc = prefill(params, prompt, cfg)
    pos = prompt.shape[0]
    out = []
    for _ in range(n_out):
        tok = jnp.argmax(logits).astype(jnp.int32)
        out.append(int(tok))
        logits, kc, vc = decode_step(params, kc, vc, pos, tok, cfg)
        pos += 1
    return out
